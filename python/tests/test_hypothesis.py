"""Hypothesis sweeps: shapes, block parameters and data distributions.

These catch block/halo indexing bugs that fixed-shape tests miss — the
Pallas grid arithmetic must hold for *every* legal (shape, block) pair."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _close(got, want, atol=1e-3):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol, rtol=1e-3)


@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 8),
    block=st.sampled_from([128, 256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_vadd_any_blocking(nblocks, block, seed):
    rng = np.random.default_rng(seed)
    n = nblocks * block
    a = rng.standard_normal(n).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    _close(K.vadd(a, b, block=block), ref.vadd(a, b))


@settings(**SETTINGS)
@given(
    mt=st.sampled_from([1, 2, 4]),
    nt=st.sampled_from([1, 2, 4]),
    kt=st.sampled_from([1, 2, 4]),
    tile=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mm_any_tiling(mt, nt, kt, tile, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((mt * tile, kt * tile)).astype(np.float32)
    b = rng.standard_normal((kt * tile, nt * tile)).astype(np.float32)
    _close(K.mm(a, b, bm=tile, bn=tile, bk=tile), ref.mm(a, b), atol=1e-2)


@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 6),
    block=st.sampled_from([128, 256]),
    taps_len=st.sampled_from([2, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fir_any_blocking(nblocks, block, taps_len, seed):
    rng = np.random.default_rng(seed)
    n = nblocks * block
    x = rng.standard_normal(n + taps_len - 1).astype(np.float32)
    taps = rng.standard_normal(taps_len).astype(np.float32)
    _close(K.fir(x, taps, block=block), ref.fir(x, taps), atol=1e-2)


@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 8),
    block=st.sampled_from([256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_histogram_any_blocking(nblocks, block, seed):
    rng = np.random.default_rng(seed)
    x = rng.random(nblocks * block).astype(np.float32)
    got = np.asarray(K.histogram(x, block=block))
    _close(got, ref.histogram(x, 256), atol=0)
    assert got.sum() == nblocks * block  # conservation under any blocking


@settings(**SETTINGS)
@given(
    hs=st.integers(1, 4),
    ws=st.integers(1, 4),
    stripe=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dct_any_shape(hs, ws, stripe, seed):
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((hs * 16, ws * 16)).astype(np.float32)
    if (hs * 16) % stripe:
        return
    _close(K.dct8x8(img, stripe=stripe), ref.dct8x8(img), atol=1e-2)


@settings(**SETTINGS)
@given(
    hstripes=st.integers(1, 4),
    stripe=st.sampled_from([8, 16, 32]),
    w=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sobel_any_stripe(hstripes, stripe, w, seed):
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((hstripes * stripe, w)).astype(np.float32)
    _close(K.sobel(img, stripe=stripe), ref.sobel(img), atol=1e-2)


@settings(**SETTINGS)
@given(
    hstripes=st.integers(1, 3),
    stripe=st.sampled_from([8, 16]),
    w=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_normal_est_any_stripe(hstripes, stripe, w, seed):
    rng = np.random.default_rng(seed)
    pts = rng.standard_normal((hstripes * stripe, w, 3)).astype(np.float32)
    _close(K.normal_est(pts, stripe=stripe), ref.normal_est(pts), atol=1e-2)


@settings(**SETTINGS)
@given(
    stripe=st.sampled_from([8, 16]),
    hstripes=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_mandelbrot_any_stripe(stripe, hstripes, seed):
    rng = np.random.default_rng(seed)
    c = (rng.standard_normal((hstripes * stripe, 32, 2)) * 1.5).astype(
        np.float32
    )
    _close(K.mandelbrot(c, stripe=stripe), ref.mandelbrot(c), atol=0)


@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 4),
    block=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_black_scholes_any_blocking(nblocks, block, seed):
    rng = np.random.default_rng(seed)
    n = nblocks * block
    p = np.stack(
        [
            rng.uniform(50, 150, n), rng.uniform(50, 150, n),
            rng.uniform(0.1, 2.0, n), rng.uniform(0.0, 0.1, n),
            rng.uniform(0.1, 0.6, n),
        ],
        axis=1,
    ).astype(np.float32)
    _close(K.black_scholes(p, block=block), ref.black_scholes(p), atol=5e-2)


@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 4),
    block=st.sampled_from([256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_aes_bijective_any_blocking(nblocks, block, seed):
    rng = np.random.default_rng(seed)
    n = nblocks * block
    x = rng.standard_normal(n).astype(np.float32)
    got = np.asarray(K.aes_arx(x, block=block)).view(np.uint32)
    want = np.asarray(ref.aes_arx(x)).view(np.uint32)
    assert (got == want).all()
