"""L2 model-level checks: every catalogued variant builds, its variants
agree numerically (resource-elastic replacement must be semantics-
preserving!), and the manifest metadata is self-consistent."""

import numpy as np
import pytest

from compile import model, specs


def _inputs(accel, seed=7):
    rng = np.random.default_rng(seed)
    out = []
    for shape in accel.in_shapes:
        if accel.name == "histogram":
            out.append(rng.random(shape).astype(np.float32))
        elif accel.name == "black_scholes":
            n = shape[0]
            out.append(
                np.stack(
                    [
                        rng.uniform(50, 150, n), rng.uniform(50, 150, n),
                        rng.uniform(0.1, 2.0, n), rng.uniform(0.0, 0.1, n),
                        rng.uniform(0.1, 0.6, n),
                    ],
                    axis=1,
                ).astype(np.float32)
            )
        else:
            out.append(rng.standard_normal(shape).astype(np.float32))
    return out


@pytest.mark.parametrize("variant", model.all_variants())
def test_variant_builds_and_matches_ref(variant):
    accel, _ = model.find(variant)
    fn, examples = model.build(variant)
    assert len(examples) == len(accel.in_shapes)
    args = _inputs(accel)
    (got,) = fn(*args)
    (want,) = model.reference(accel.name)(*args)
    assert got.shape == tuple(accel.out_shapes[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-2, rtol=1e-3)


@pytest.mark.parametrize("accel", specs.ACCELERATORS,
                         ids=lambda a: a.name)
def test_variants_agree(accel):
    """Replacement invariant: switching implementation alternatives must
    not change results (§4.4.2)."""
    args = _inputs(accel)
    outs = []
    for v in accel.variants:
        fn, _ = model.build(v.name)
        outs.append(np.asarray(fn(*args)[0]))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("accel", specs.ACCELERATORS,
                         ids=lambda a: a.name)
def test_spec_consistency(accel):
    assert accel.lang in ("c", "opencl", "rtl")
    assert accel.bytes_in == sum(4 * int(np.prod(s)) for s in accel.in_shapes)
    names = [v.name for v in accel.variants]
    assert len(set(names)) == len(names)
    prev_cycles = None
    for v in accel.variants:
        assert v.regions >= 1
        assert v.netlist.luts > 0
        # A variant must fit the regions it claims (Ultra96 scale).
        assert v.netlist.luts <= specs.REGION_LUTS * v.regions
        assert v.netlist.brams <= specs.REGION_BRAMS * v.regions
        assert v.netlist.dsps <= specs.REGION_DSPS * v.regions
        if prev_cycles is not None:
            assert v.cycles < prev_cycles  # bigger variant = faster (Pareto)
        prev_cycles = v.cycles


def test_dct_superlinear_cycle_model():
    accel = specs.BY_NAME["dct"]
    v1, v2 = accel.variants
    assert v2.regions == 2 * v1.regions
    speedup = v1.cycles / v2.cycles
    assert 3.4 <= speedup <= 3.7  # the paper's 3.55x (Fig 19)


def test_table3_workload_utilisations():
    for name, util in specs.TABLE3_WORKLOADS:
        v1 = specs.BY_NAME[name].variants[0]
        assert abs(v1.netlist.util_of_regions(1) - util) < 0.02
