"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel, at every catalogued variant's block parameters, must
match the pure-jnp oracle in ref.py."""

import numpy as np
import pytest

from compile import kernels as K
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def assert_close(got, want, atol=1e-4, rtol=1e-4):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol, rtol=rtol)


@pytest.mark.parametrize("block", [512, 1024, 2048])
def test_vadd(block):
    a = RNG.standard_normal(4096).astype(np.float32)
    b = RNG.standard_normal(4096).astype(np.float32)
    assert_close(K.vadd(a, b, block=block), ref.vadd(a, b))


@pytest.mark.parametrize("tile", [16, 32, 64])
def test_mm(tile):
    a = RNG.standard_normal((64, 64)).astype(np.float32)
    b = RNG.standard_normal((64, 64)).astype(np.float32)
    assert_close(K.mm(a, b, bm=tile, bn=tile, bk=tile), ref.mm(a, b),
                 atol=1e-3)


def test_mm_rectangular():
    a = RNG.standard_normal((32, 128)).astype(np.float32)
    b = RNG.standard_normal((128, 64)).astype(np.float32)
    assert_close(K.mm(a, b, bm=16, bn=32, bk=64), ref.mm(a, b), atol=1e-3)


@pytest.mark.parametrize("block", [1024, 2048])
@pytest.mark.parametrize("taps_len", [4, 16])
def test_fir(block, taps_len):
    taps = RNG.standard_normal(taps_len).astype(np.float32)
    x = RNG.standard_normal(4096 + taps_len - 1).astype(np.float32)
    assert_close(K.fir(x, taps, block=block), ref.fir(x, taps), atol=1e-3)


@pytest.mark.parametrize("block", [1024, 2048])
def test_histogram(block):
    x = RNG.random(4096).astype(np.float32)
    assert_close(K.histogram(x, block=block), ref.histogram(x, 256))


def test_histogram_mass_conserved():
    x = RNG.random(8192).astype(np.float32)
    h = np.asarray(K.histogram(x, block=1024))
    assert h.sum() == 8192.0
    assert (h >= 0).all()


def test_histogram_boundary_values():
    # 0.0 lands in bin 0; values ~1.0 clamp into the last bin.
    x = np.asarray([0.0, 0.9999999, 0.5] + [0.25] * 1021, np.float32)
    h = np.asarray(K.histogram(x, block=1024))
    assert h[0] >= 1 and h[255] >= 1


@pytest.mark.parametrize("stripe", [8, 16, 32])
def test_dct(stripe):
    img = RNG.standard_normal((64, 64)).astype(np.float32)
    assert_close(K.dct8x8(img, stripe=stripe), ref.dct8x8(img), atol=1e-3)


def test_dct_energy_preserved():
    # Orthonormal transform: Parseval's identity per 8x8 block.
    img = RNG.standard_normal((64, 64)).astype(np.float32)
    out = np.asarray(K.dct8x8(img, stripe=8))
    np.testing.assert_allclose((out ** 2).sum(), (img ** 2).sum(), rtol=1e-3)


@pytest.mark.parametrize("stripe", [32, 64])
def test_sobel(stripe):
    img = RNG.standard_normal((128, 128)).astype(np.float32)
    assert_close(K.sobel(img, stripe=stripe), ref.sobel(img), atol=1e-3)


def test_sobel_flat_image_is_zero_inside():
    img = np.full((64, 64), 3.0, np.float32)
    out = np.asarray(K.sobel(img, stripe=32))
    assert np.abs(out[2:-2, 2:-2]).max() < 1e-5  # flat interior -> no edges
    assert out[0].max() > 0  # zero-padded border produces an edge


@pytest.mark.parametrize("stripe", [32, 64])
def test_normal_est(stripe):
    pts = RNG.standard_normal((64, 64, 3)).astype(np.float32)
    assert_close(K.normal_est(pts, stripe=stripe), ref.normal_est(pts),
                 atol=1e-3)


def test_normal_est_unit_length():
    pts = RNG.standard_normal((64, 64, 3)).astype(np.float32)
    n = np.asarray(K.normal_est(pts, stripe=32))
    lens = np.linalg.norm(n, axis=-1)
    mask = lens > 1e-6  # degenerate (parallel-diff) points stay ~0
    np.testing.assert_allclose(lens[mask], 1.0, atol=1e-3)


@pytest.mark.parametrize("stripe", [32, 64])
def test_mandelbrot(stripe):
    g = np.meshgrid(np.linspace(-2, 1, 64), np.linspace(-1.5, 1.5, 64),
                    indexing="ij")
    c = np.stack(g, -1).astype(np.float32)
    assert_close(K.mandelbrot(c, stripe=stripe), ref.mandelbrot(c))


def test_mandelbrot_known_points():
    # c = 0 never escapes (count == iters); c = 2 escapes after 1 round.
    c = np.zeros((32, 64, 2), np.float32)
    c[0, 1] = [2.0, 0.0]
    out = np.asarray(K.mandelbrot(c, stripe=32))
    assert out[0, 0] == 64.0
    assert out[0, 1] <= 2.0


@pytest.mark.parametrize("block", [1024, 2048])
def test_black_scholes(block):
    n = 4096
    p = np.stack(
        [
            RNG.uniform(50, 150, n), RNG.uniform(50, 150, n),
            RNG.uniform(0.1, 2.0, n), RNG.uniform(0.0, 0.1, n),
            RNG.uniform(0.1, 0.6, n),
        ],
        axis=1,
    ).astype(np.float32)
    assert_close(K.black_scholes(p, block=block), ref.black_scholes(p),
                 atol=1e-2)


def test_black_scholes_put_call_parity():
    n = 1024
    p = np.stack(
        [
            RNG.uniform(80, 120, n), RNG.uniform(80, 120, n),
            RNG.uniform(0.25, 1.0, n), RNG.uniform(0.01, 0.05, n),
            RNG.uniform(0.15, 0.4, n),
        ],
        axis=1,
    ).astype(np.float32)
    out = np.asarray(K.black_scholes(p, block=1024))
    s, k, t, r = p[:, 0], p[:, 1], p[:, 2], p[:, 3]
    parity = out[:, 0] - out[:, 1]  # C - P = S - K e^{-rT}
    np.testing.assert_allclose(parity, s - k * np.exp(-r * t),
                               atol=5e-2, rtol=1e-3)


def test_aes_matches_ref_bit_exact():
    x = RNG.standard_normal(4096).astype(np.float32)
    got = np.asarray(K.aes_arx(x, block=1024)).view(np.uint32)
    want = np.asarray(ref.aes_arx(x)).view(np.uint32)
    assert (got == want).all()


def test_aes_is_a_permutation_of_bits():
    # ARX rounds are bijective on u32 — distinct inputs stay distinct.
    x = np.arange(1024, dtype=np.float32)
    out = np.asarray(K.aes_arx(x, block=1024)).view(np.uint32)
    assert len(np.unique(out)) == 1024


def test_block_mismatch_raises():
    a = np.zeros(1000, np.float32)
    with pytest.raises(ValueError):
        K.vadd(a, a, block=512)
    with pytest.raises(ValueError):
        K.histogram(a, block=512)
    with pytest.raises(ValueError):
        K.fir(np.zeros(1015, np.float32), np.zeros(16, np.float32),
              block=512)
