"""AOT pipeline checks: lowering emits parseable HLO text with the right
entry signature, and the manifest matches the spec catalog."""

import json
import os

import pytest

from compile import aot, model, specs


def test_lower_vadd_entry_signature():
    text = aot.lower_variant("vadd_v1")
    assert "ENTRY" in text
    assert "f32[4096]" in text
    # return_tuple=True: entry root is a tuple (the rust side unwraps it).
    assert "(f32[4096]" in text


def test_lower_is_deterministic():
    assert aot.lower_variant("dct_v1") == aot.lower_variant("dct_v1")


def test_manifest_entry_schema():
    e = aot.manifest_entry(specs.BY_NAME["sobel"])
    assert e["name"] == "sobel"
    assert e["registers"][0] == {"name": "control", "offset": 0}
    offsets = [r["offset"] for r in e["registers"][1:]]
    assert offsets == [16 + 8 * i for i in range(len(offsets))]
    for v in e["variants"]:
        assert v["clock_hz"] == specs.CLOCK_HZ
        assert v["hlo"].endswith(".hlo.txt")
        assert set(v["netlist"]) == {"luts", "ffs", "brams", "dsps"}


def test_manifest_covers_all_variants():
    entries = [aot.manifest_entry(a) for a in specs.ACCELERATORS]
    names = [v["name"] for e in entries for v in e["variants"]]
    assert sorted(names) == sorted(model.all_variants())


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "..", "..", "artifacts",
                                    "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_manifest_is_fresh():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        m = json.load(f)
    assert m["version"] == aot.MANIFEST_VERSION
    for fname in m["checksums"]:
        assert os.path.exists(os.path.join(root, fname)), fname
    built = {a["name"] for a in m["accelerators"]}
    assert built == {a.name for a in specs.ACCELERATORS}
