"""L2: the JAX compute graph for every accelerator variant.

Each variant is a jittable function over fixed work-item shapes (HLO is
shape-specialised), calling the L1 Pallas kernel for the hot spot and
doing any pre/post graph work (halo materialisation, padding) in plain
jnp — exactly the split an HLS module has between its DMA prologue and
its datapath. ``build(variant)`` returns ``(fn, example_args)`` ready for
``jax.jit(fn).lower(*example_args)`` in aot.py.
"""

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from . import specs
from . import kernels as K


def _examples(shapes) -> List[jax.ShapeDtypeStruct]:
    return [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]


def build(variant_name: str) -> Tuple[Callable, List[jax.ShapeDtypeStruct]]:
    """(traceable fn, example args) for one accelerator variant."""
    accel, variant = find(variant_name)
    p = variant.kernel_params
    name = accel.name

    if name == "vadd":
        fn = lambda a, b: (K.vadd(a, b, block=p["block"]),)
    elif name == "mm":
        fn = lambda a, b: (K.mm(a, b, bm=p["bm"], bn=p["bn"], bk=p["bk"]),)
    elif name == "fir":
        fn = lambda x, t: (K.fir(x, t, block=p["block"]),)
    elif name == "histogram":
        fn = lambda x: (K.histogram(x, block=p["block"]),)
    elif name == "dct":
        fn = lambda img: (K.dct8x8(img, stripe=p["stripe"]),)
    elif name == "sobel":
        fn = lambda img: (K.sobel(img, stripe=p["stripe"]),)
    elif name == "normal_est":
        fn = lambda pts: (K.normal_est(pts, stripe=p["stripe"]),)
    elif name == "mandelbrot":
        fn = lambda c: (K.mandelbrot(c, stripe=p["stripe"]),)
    elif name == "black_scholes":
        fn = lambda prm: (K.black_scholes(prm, block=p["block"]),)
    elif name == "aes":
        fn = lambda x: (K.aes_arx(x, block=p.get("block", 1024)),)
    else:
        raise KeyError(f"unknown accelerator {name!r}")

    return fn, _examples(accel.in_shapes)


def reference(accel_name: str) -> Callable:
    """The pure-jnp oracle with the same signature as build()'s fn."""
    r = K.ref
    return {
        "vadd": lambda a, b: (r.vadd(a, b),),
        "mm": lambda a, b: (r.mm(a, b),),
        "fir": lambda x, t: (r.fir(x, t),),
        "histogram": lambda x: (r.histogram(x, 256),),
        "dct": lambda img: (r.dct8x8(img),),
        "sobel": lambda img: (r.sobel(img),),
        "normal_est": lambda pts: (r.normal_est(pts),),
        "mandelbrot": lambda c: (r.mandelbrot(c),),
        "black_scholes": lambda prm: (r.black_scholes(prm),),
        "aes": lambda x: (r.aes_arx(x),),
    }[accel_name]


def find(variant_name: str):
    for accel in specs.ACCELERATORS:
        for v in accel.variants:
            if v.name == variant_name:
                return accel, v
    raise KeyError(f"unknown variant {variant_name!r}")


def all_variants() -> List[str]:
    return [v.name for a in specs.ACCELERATORS for v in a.variants]
