"""vadd — the paper's Listing-2 example accelerator (c = a + b).

TPU adaptation: the HLS variant's unroll factor (number of parallel adders
in the PR region) maps to the Pallas block length — variant v1 streams
1024-lane blocks (one 8x128 VPU tile), v2 streams 2048-lane blocks (two
tiles per grid step, i.e. double the datapath, half the grid iterations),
mirroring a 2-region module with twice the adder columns.

VMEM per grid step: 3 blocks x block x 4 B (v1: 12 KiB, v2: 24 KiB).
MXU: unused (pure VPU kernel).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pallas_call


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def vadd(a, b, *, block: int = 1024):
    """Blocked vector add. ``a``/``b``: f32[n], n % block == 0."""
    n = a.shape[0]
    if n % block:
        raise ValueError(f"vadd: n={n} not a multiple of block={block}")
    grid = (cdiv(n, block),)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
    )(a, b)
