"""Pure-jnp oracles for every accelerator kernel.

These are the correctness ground truth for the Pallas kernels (L1): pytest
asserts ``allclose(kernel(x), ref(x))`` for every accelerator and variant.
They are deliberately written in the most direct jnp style — no tiling, no
Pallas — so a reviewer can audit them against the textbook definition of
each benchmark (Spector suite [33] + the paper's in-house accelerators).
"""

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Simple element-wise / streaming accelerators
# ---------------------------------------------------------------------------


def vadd(a, b):
    """Vector addition — the paper's Listing-2 example accelerator."""
    return a + b


def fir(x, taps):
    """1-D FIR filter (Spector): y[i] = sum_k taps[k] * x[i + k].

    ``x`` is pre-padded by the caller: len(y) = len(x) - len(taps) + 1.
    """
    n = x.shape[0] - taps.shape[0] + 1
    idx = jnp.arange(n)[:, None] + jnp.arange(taps.shape[0])[None, :]
    return (x[idx] * taps[None, :]).sum(axis=1)


def mm(a, b):
    """Dense matrix multiply (Spector MM)."""
    return a @ b


def histogram(x, bins):
    """``bins``-bin histogram of values in [0, 1) (Spector HIST).

    Counts are returned as f32 so the whole artifact surface stays f32
    (see DESIGN.md — single-dtype interchange keeps the PJRT bridge simple).
    """
    idx = jnp.clip((x * bins).astype(jnp.int32), 0, bins - 1)
    return jnp.zeros((bins,), jnp.float32).at[idx].add(1.0)


# ---------------------------------------------------------------------------
# Block accelerators
# ---------------------------------------------------------------------------


def dct_matrix(n=8):
    """Orthonormal DCT-II basis matrix."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    m = np.sqrt(2.0 / n) * np.cos(np.pi * (2 * i + 1) * k / (2 * n))
    m[0, :] = 1.0 / np.sqrt(n)
    return jnp.asarray(m, jnp.float32)


def dct8x8(img):
    """8x8 blocked 2-D DCT (Spector DCT) over an (H, W) image tile."""
    h, w = img.shape
    d = dct_matrix(8)
    blocks = img.reshape(h // 8, 8, w // 8, 8).transpose(0, 2, 1, 3)
    out = jnp.einsum("ij,bcjk,lk->bcil", d, blocks, d)
    return out.transpose(0, 2, 1, 3).reshape(h, w)


def sobel(img):
    """3x3 Sobel gradient magnitude (zero-padded borders).

    The paper's memory-bound accelerator (Xilinx SDAccel examples [39]).
    """
    p = jnp.pad(img, 1)
    gx = (
        p[:-2, :-2] - p[:-2, 2:]
        + 2.0 * (p[1:-1, :-2] - p[1:-1, 2:])
        + p[2:, :-2] - p[2:, 2:]
    )
    gy = (
        p[:-2, :-2] - p[2:, :-2]
        + 2.0 * (p[:-2, 1:-1] - p[2:, 1:-1])
        + p[:-2, 2:] - p[2:, 2:]
    )
    return jnp.sqrt(gx * gx + gy * gy)


def normal_est(points):
    """Surface-normal estimation (Spector NORM) over an (H, W, 3) grid.

    Normal = normalised cross product of the forward differences along the
    two grid axes (edge rows/cols clamp to their neighbour's value).
    """
    du = jnp.diff(points, axis=0, append=points[-1:, :, :])
    dv = jnp.diff(points, axis=1, append=points[:, -1:, :])
    n = jnp.cross(du, dv)
    norm = jnp.linalg.norm(n, axis=-1, keepdims=True)
    return n / jnp.maximum(norm, 1e-8)


# ---------------------------------------------------------------------------
# Compute-bound accelerators (the paper's in-house C / OpenCL modules)
# ---------------------------------------------------------------------------


def mandelbrot(coords, iters=64):
    """Mandelbrot escape-iteration count over an (H, W, 2) coordinate grid.

    coords[..., 0] = Re(c), coords[..., 1] = Im(c); returns f32 counts.
    """
    cr, ci = coords[..., 0], coords[..., 1]

    def body(_, st):
        zr, zi, cnt = st
        zr2, zi2 = zr * zr, zi * zi
        inside = (zr2 + zi2) <= 4.0
        nzr = jnp.where(inside, zr2 - zi2 + cr, zr)
        nzi = jnp.where(inside, 2.0 * zr * zi + ci, zi)
        return nzr, nzi, cnt + inside.astype(jnp.float32)

    zr = jnp.zeros_like(cr)
    zi = jnp.zeros_like(ci)
    cnt = jnp.zeros_like(cr)
    _, _, cnt = jax.lax.fori_loop(0, iters, body, (zr, zi, cnt))
    return cnt


def _norm_cdf(x):
    return 0.5 * (1.0 + jax.lax.erf(x / jnp.sqrt(2.0).astype(x.dtype)))


def black_scholes(params):
    """European call/put pricing (Black-Scholes closed form [37]).

    params: (N, 5) columns = spot S, strike K, time T, rate r, vol sigma.
    Returns (N, 2) = [call, put].
    """
    s, k, t, r, sig = (params[:, i] for i in range(5))
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(s / k) + (r + 0.5 * sig * sig) * t) / (sig * sqrt_t)
    d2 = d1 - sig * sqrt_t
    disc = k * jnp.exp(-r * t)
    call = s * _norm_cdf(d1) - disc * _norm_cdf(d2)
    put = disc * _norm_cdf(-d2) - s * _norm_cdf(-d1)
    return jnp.stack([call, put], axis=1)


# ---------------------------------------------------------------------------
# AES-like ARX cipher (the Table-3 "sparse" RTL module)
# ---------------------------------------------------------------------------

AES_ROUNDS = 8
AES_KEY = (0x9E3779B9, 0x7F4A7C15, 0x85EBCA6B, 0xC2B2AE35)


def aes_arx(x_f32):
    """ARX round function over the *bit pattern* of an f32 vector.

    The real FOS AES module is hand-written RTL; here the interchange stays
    f32 (bitcast in/out) and the rounds are add/rotate/xor on u32 lanes —
    the same dataflow class, so the PnR netlist shape and the runtime path
    are exercised identically. NOT cryptographically meaningful.
    """
    x = jax.lax.bitcast_convert_type(x_f32, jnp.uint32)

    def rotl(v, r):
        return (v << jnp.uint32(r)) | (v >> jnp.uint32(32 - r))

    def rnd(i, v):
        k = jnp.uint32(AES_KEY[0])
        for kk in AES_KEY[1:]:
            k = k ^ jnp.uint32(kk) + jnp.uint32(0)  # fold key material
        v = v + jnp.uint32(AES_KEY[0])
        v = rotl(v, 7) ^ jnp.uint32(AES_KEY[1])
        v = v + jnp.uint32(AES_KEY[2])
        v = rotl(v, 13) ^ k
        return v

    x = jax.lax.fori_loop(0, AES_ROUNDS, rnd, x)
    return jax.lax.bitcast_convert_type(x, jnp.float32)
