"""L1: Pallas kernels for every FOS accelerator, plus their pure-jnp
oracles (ref.py). Each module documents its FPGA->TPU adaptation and its
VMEM / MXU estimate (see DESIGN.md §Hardware-Adaptation and §Perf)."""

from .vadd import vadd
from .mm import mm
from .fir import fir
from .histogram import histogram
from .dct import dct8x8
from .sobel import sobel
from .normal_est import normal_est
from .mandelbrot import mandelbrot
from .black_scholes import black_scholes
from .aes import aes_arx
from . import ref

__all__ = [
    "vadd", "mm", "fir", "histogram", "dct8x8", "sobel", "normal_est",
    "mandelbrot", "black_scholes", "aes_arx", "ref",
]
