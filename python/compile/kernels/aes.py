"""aes — ARX stream transform standing in for the paper's AES RTL module.

The real FOS AES is hand-written RTL used in Table 3 as the *sparse*
(33%-utilisation) compile workload. Its netlist spec (see specs.py) drives
the PnR simulator; this kernel exists so the module is also *executable*
through the same PJRT path as every other accelerator. The interchange
surface stays f32 — the kernel bitcasts to u32 lanes, runs 8 ARX rounds
(add / rotate / xor, the dataflow class of a round-based cipher), and
bitcasts back. NOT cryptographically meaningful.

TPU adaptation: byte-wise S-box lookups are gather-hostile; ARX rounds are
pure VPU integer ops, the standard TPU-friendly cipher structure.

VMEM per grid step: 2 x block u32 (v1 @1024: 8 KiB). MXU: unused.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pallas_call
from .ref import AES_KEY, AES_ROUNDS


def _kernel(x_ref, o_ref):
    x = jax.lax.bitcast_convert_type(x_ref[...], jnp.uint32)

    def rotl(v, r):
        return (v << jnp.uint32(r)) | (v >> jnp.uint32(32 - r))

    k = jnp.uint32(AES_KEY[0])
    for kk in AES_KEY[1:]:
        k = k ^ jnp.uint32(kk) + jnp.uint32(0)

    def rnd(i, v):
        v = v + jnp.uint32(AES_KEY[0])
        v = rotl(v, 7) ^ jnp.uint32(AES_KEY[1])
        v = v + jnp.uint32(AES_KEY[2])
        v = rotl(v, 13) ^ k
        return v

    x = jax.lax.fori_loop(0, AES_ROUNDS, rnd, x)
    o_ref[...] = jax.lax.bitcast_convert_type(x, jnp.float32)


def aes_arx(x, *, block: int = 1024):
    """ARX-transform the bit patterns of f32[n]; n % block == 0."""
    n = x.shape[0]
    if n % block:
        raise ValueError(f"aes: n={n} not a multiple of block={block}")
    grid = (cdiv(n, block),)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
    )(x)
