"""black_scholes — European option pricing (Monte-Carlo paper's closed
form [37]; the paper's dense 81%-utilisation HLS module).

TPU adaptation: the FPGA module instantiates deep exp/log/erf cordic
pipelines; the TPU equivalent evaluates the closed form on the VPU's
transcendental units over a VMEM block of option records. Variant = block
length (number of parallel pricing pipelines).

VMEM per grid step: block x 5 in + block x 2 out (v2 @2048: 56 KiB).
MXU: unused (transcendental-bound).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pallas_call


def _kernel(p_ref, o_ref):
    p = p_ref[...]
    s, k, t, r, sig = (p[:, i] for i in range(5))
    sqrt_t = jnp.sqrt(t)
    rt2 = jnp.sqrt(jnp.float32(2.0))
    d1 = (jnp.log(s / k) + (r + 0.5 * sig * sig) * t) / (sig * sqrt_t)
    d2 = d1 - sig * sqrt_t
    cdf = lambda x: 0.5 * (1.0 + jax.lax.erf(x / rt2))
    disc = k * jnp.exp(-r * t)
    call = s * cdf(d1) - disc * cdf(d2)
    put = disc * cdf(-d2) - s * cdf(-d1)
    o_ref[...] = jnp.stack([call, put], axis=1)


def black_scholes(params, *, block: int = 1024):
    """Price (N, 5) option records -> (N, 2) [call, put]; N % block == 0."""
    n = params.shape[0]
    if n % block:
        raise ValueError(f"black_scholes: n={n} not a multiple of {block}")
    grid = (cdiv(n, block),)
    return pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block, 5), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2), jnp.float32),
    )(params)
