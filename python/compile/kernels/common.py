"""Shared Pallas plumbing for all FOS accelerator kernels.

Every kernel in this package is lowered with ``interpret=True``: the CPU
PJRT client (xla_extension 0.5.1) cannot execute Mosaic custom-calls, so
interpret mode is the only path that round-trips through the Rust runtime.
On a real TPU the same kernels lower to Mosaic; the BlockSpec choices below
are made for that target (tiles padded to the 8x128 VPU lane layout, MXU
tiles of 128 where a matmul is involved) and the per-variant VMEM/MXU
estimates live in each kernel's docstring + DESIGN.md §Perf.
"""

import functools

import jax
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pallas_call(kernel, **kwargs):
    """pl.pallas_call with the FOS-wide interpret policy applied."""
    return pl.pallas_call(kernel, interpret=INTERPRET, **kwargs)
