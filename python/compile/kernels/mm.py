"""mm — dense matrix multiply (Spector MM benchmark).

TPU adaptation: the Spector OpenCL kernel tiles A/B into local memory with
a compile-time ``BLOCK`` knob; here the same knob is the Pallas BlockSpec
tile. v1 uses 32x32 tiles (half-MXU), v2 uses 64x64 tiles — a 2-region
module with a doubled systolic footprint. The K reduction runs as the
innermost grid dimension with an accumulate-into-output pattern, which is
the canonical Pallas matmul schedule (HBM->VMEM streaming of A and B
panels replaces the AXI burst schedule of the FPGA DMA engines).

VMEM per grid step: (bm*bk + bk*bn + bm*bn) * 4 B (v2 @64: 48 KiB).
MXU: dot(bm x bk, bk x bn) per step — full occupancy at 128, ~25% at 64.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pallas_call


def _kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def mm(a, b, *, bm: int = 32, bn: int = 32, bk: int = 32):
    """Tiled matmul. a: f32[m,k], b: f32[k,n]; dims divisible by tiles."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (k, k2)
    for dim, t, nm in ((m, bm, "m"), (n, bn, "n"), (k, bk, "k")):
        if dim % t:
            raise ValueError(f"mm: {nm}={dim} not a multiple of its tile {t}")
    grid = (cdiv(m, bm), cdiv(n, bn), cdiv(k, bk))
    return pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
    )(a, b)
