"""mandelbrot — escape-time iteration (the paper's in-house C accelerator).

TPU adaptation: the FPGA module is a deeply-pipelined iteration engine
(one pixel in flight per stage); the TPU formulation runs the whole VMEM
panel through a fori_loop of fused VPU multiply-adds with an in-bounds
mask — the panel width is the vector-lane analogue of the pipeline depth.
Variant = panel stripe height (replicated engines across PR regions).

Compute-bound: ~9 flops x ITERS per pixel vs 12 B of DDR traffic — the
opposite regime from sobel, which is what Fig 22's mixed-tenant experiment
exercises.

VMEM per grid step: 4 x stripe x w f32 panels (v2 @32x64: 32 KiB).
MXU: unused.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pallas_call

ITERS = 64


def _make_kernel(iters: int):
    def kernel(c_ref, o_ref):
        c = c_ref[0]  # (stripe, w, 2)
        cr, ci = c[..., 0], c[..., 1]

        def body(_, st):
            zr, zi, cnt = st
            zr2, zi2 = zr * zr, zi * zi
            inside = (zr2 + zi2) <= 4.0
            nzr = jnp.where(inside, zr2 - zi2 + cr, zr)
            nzi = jnp.where(inside, 2.0 * zr * zi + ci, zi)
            return nzr, nzi, cnt + inside.astype(jnp.float32)

        zr = jnp.zeros_like(cr)
        zi = jnp.zeros_like(ci)
        cnt = jnp.zeros_like(cr)
        _, _, cnt = jax.lax.fori_loop(0, iters, body, (zr, zi, cnt))
        o_ref[...] = cnt

    return kernel


def mandelbrot(coords, *, stripe: int = 32, iters: int = ITERS):
    """Escape counts for an (H, W, 2) grid of complex-plane coordinates."""
    h, w, _ = coords.shape
    if h % stripe:
        raise ValueError(f"mandelbrot: H={h} not a multiple of {stripe}")
    grid = (cdiv(h, stripe),)
    return pallas_call(
        _make_kernel(iters),
        grid=grid,
        in_specs=[pl.BlockSpec((1, stripe, w, 2), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((stripe, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
    )(coords.reshape(h // stripe, stripe, w, 2))
