"""dct — 8x8 blocked 2-D DCT (Spector DCT benchmark).

TPU adaptation: the FPGA variant knobs are the number of row buffers and
the butterfly unroll factor; on TPU the 8x8 basis contraction D.B.D^T is
expressed as two small matmuls per block, batched over a (rows x cols)
panel of blocks held in VMEM. The variant maps to the panel height: v1
processes one 8-row stripe of blocks per grid step, v2 processes four
stripes (more VMEM buffers <-> more BRAM row buffers, fewer grid steps).

This is the paper's *super-linear* accelerator (Fig 19): the 2-region
variant also raises the butterfly unroll, so its cycle model is ~3.55x
faster at 2x resources (see specs.py).

VMEM per grid step: panel + output panel + 8x8 basis (v2 @32x64: ~16 KiB).
MXU: 8x8 matmuls — small; batched into (panel/8, 8, 8) einsum to fill lanes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pallas_call
from . import ref


def _make_kernel(stripe: int, width: int):
    def kernel(img_ref, d_ref, o_ref):
        img = img_ref[...]
        d = d_ref[...]
        blocks = img.reshape(stripe // 8, 8, width // 8, 8).transpose(0, 2, 1, 3)
        out = jnp.einsum("ij,bcjk,lk->bcil", d, blocks, d)
        o_ref[...] = out.transpose(0, 2, 1, 3).reshape(stripe, width)

    return kernel


def dct8x8(img, *, stripe: int = 8):
    """Blocked 2-D DCT of an (H, W) tile; H % stripe == 0, stripe % 8 == 0."""
    h, w = img.shape
    if h % stripe or stripe % 8 or w % 8:
        raise ValueError(f"dct8x8: bad shape {img.shape} for stripe={stripe}")
    d = ref.dct_matrix(8)
    grid = (cdiv(h, stripe),)
    return pallas_call(
        _make_kernel(stripe, w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((stripe, w), lambda i: (i, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((stripe, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
    )(img, d)
