"""sobel — 3x3 Sobel gradient magnitude (Xilinx SDAccel examples [39]).

TPU adaptation: the FPGA kernel is a line-buffer pipeline (3 BRAM line
buffers, one pixel/cycle); the TPU equivalent keeps a (stripe + 2)-row halo
panel in VMEM per grid step and computes all eight shifted taps as static
slices of the panel — the halo rows play the role of the line buffers.
Variant = stripe height (rows per grid step <-> pipeline replication).

This is the paper's *memory-bound* accelerator: ~2 B of DDR traffic per
flop, so its latency in Figs 20-22 is dominated by the memsim AXI model,
not the cycle model.

VMEM per grid step: (stripe+2) x (w+2) halo panel + stripe x w out
(v2 @64x128: ~66 KiB). MXU: unused.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pallas_call


def _make_kernel(stripe: int, width: int):
    def kernel(p_ref, o_ref):
        p = p_ref[0]  # (stripe + 2, width + 2) halo panel

        def tap(dy, dx):
            return jax.lax.dynamic_slice(p, (dy, dx), (stripe, width))

        gx = (
            tap(0, 0) - tap(0, 2)
            + 2.0 * (tap(1, 0) - tap(1, 2))
            + tap(2, 0) - tap(2, 2)
        )
        gy = (
            tap(0, 0) - tap(2, 0)
            + 2.0 * (tap(0, 1) - tap(2, 1))
            + tap(0, 2) - tap(2, 2)
        )
        o_ref[...] = jnp.sqrt(gx * gx + gy * gy)

    return kernel


def sobel(img, *, stripe: int = 32):
    """Sobel magnitude of an (H, W) tile, zero-padded borders."""
    h, w = img.shape
    if h % stripe:
        raise ValueError(f"sobel: H={h} not a multiple of stripe={stripe}")
    padded = jnp.pad(img, 1)  # L2 prologue — the DMA writes the halo
    grid = (cdiv(h, stripe),)
    return pallas_call(
        _make_kernel(stripe, w),
        grid=grid,
        in_specs=[
            # Overlapping halo stripes: load the whole padded image and
            # slice in-kernel is avoided by passing stripe-indexed blocks
            # of the padded array with a 2-row halo. Pallas block indices
            # cannot overlap, so the halo panel is materialised by the L2
            # wrapper as a (grid, stripe+2, w+2) stack.
            pl.BlockSpec((1, stripe + 2, w + 2), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((stripe, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
    )(_halo_stack(padded, stripe, h, w))


def _halo_stack(padded, stripe, h, w):
    """(grid, stripe+2, w+2) stack of overlapping halo panels (L2-side)."""
    n = h // stripe
    starts = jnp.arange(n) * stripe
    return jax.vmap(
        lambda s: jax.lax.dynamic_slice(padded, (s, 0), (stripe + 2, w + 2))
    )(starts)
