"""fir — 1-D FIR filter (Spector FIR benchmark).

TPU adaptation: the FPGA implementation is a tap-delay line with one MAC
per tap; on TPU the delay line becomes a shifted-slice contraction: each
grid step slices a (block + taps - 1) window of x out of VMEM and runs the
tap loop as a statically-unrolled VPU MAC chain — the unroll factor
(parallel MACs in the PR region) maps to the block length. Because the
windows of adjacent grid steps overlap by (taps - 1) elements (a halo),
the input is kept whole in VMEM and sliced per step rather than blocked
by BlockSpec (Pallas block indices cannot express overlapping windows).

VMEM: whole signal + taps + one output block (v2 @ n=4096: ~25 KiB).
MXU: unused (taps=16 contraction runs on the VPU).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pallas_call


def _make_kernel(taps_len: int, block: int):
    def kernel(x_ref, t_ref, o_ref):
        i = pl.program_id(0)
        window = jax.lax.dynamic_slice(
            x_ref[...], (i * block,), (block + taps_len - 1,)
        )
        taps = t_ref[...]
        acc = jnp.zeros((block,), jnp.float32)
        for j in range(taps_len):  # static unroll — the FPGA MAC array
            acc = acc + taps[j] * jax.lax.dynamic_slice(window, (j,), (block,))
        o_ref[...] = acc

    return kernel


def fir(x, taps, *, block: int = 1024):
    """y[i] = sum_j taps[j] * x[i+j]; x: f32[n + taps - 1] pre-padded."""
    taps_len = taps.shape[0]
    n = x.shape[0] - taps_len + 1
    if n % block:
        raise ValueError(f"fir: n={n} not a multiple of block={block}")
    grid = (cdiv(n, block),)
    return pallas_call(
        _make_kernel(taps_len, block),
        grid=grid,
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0,)),  # whole signal (halo reads)
            pl.BlockSpec((taps_len,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
    )(x, taps)
