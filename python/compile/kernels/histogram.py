"""histogram — 256-bin histogram (Spector HIST benchmark).

TPU adaptation: the FPGA kernel uses a BRAM scatter with read-modify-write
conflict resolution; scatter is hostile to both the VPU and the MXU, so the
TPU formulation is the classic one-hot contraction: each grid step builds a
(block, bins) one-hot matrix from the bin indices and reduces it with a
(1, block) x (block, bins) matmul — turning the scatter into MXU work.
Partial histograms accumulate into the output block across grid steps
(same accumulate-into-output schedule as the matmul K loop).

VMEM per grid step: block + block*bins one-hot (v1 @1024x256: ~1 MiB).
MXU: (block x bins) contraction per step — the whole kernel is MXU-bound.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pallas_call


def _make_kernel(bins: int, block: int):
    def kernel(x_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        x = x_ref[...]
        idx = jnp.clip((x * bins).astype(jnp.int32), 0, bins - 1)
        onehot = (idx[:, None] == jnp.arange(bins)[None, :]).astype(jnp.float32)
        o_ref[...] += jnp.ones((1, block), jnp.float32) @ onehot

    return kernel


def histogram(x, *, bins: int = 256, block: int = 1024):
    """f32 bin counts of x values in [0, 1). x: f32[n], n % block == 0."""
    n = x.shape[0]
    if n % block:
        raise ValueError(f"histogram: n={n} not a multiple of block={block}")
    grid = (cdiv(n, block),)
    out = pallas_call(
        _make_kernel(bins, block),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, bins), jnp.float32),
    )(x)
    return out[0]
