"""normal_est — surface-normal estimation (Spector NORM benchmark).

TPU adaptation: the FPGA kernel streams a point-cloud grid through a
window pipeline computing cross products of forward differences; on TPU
each grid step holds a (stripe + 1)-row halo panel of (x, y, z) points in
VMEM, forms the two difference fields with static slices, and evaluates
the cross product + rsqrt normalisation on the VPU. Variant = stripe
height (pipeline replication across PR regions).

VMEM per grid step: (stripe+1) x (w+1) x 3 panel + stripe x w x 3 out
(v2 @32x64: ~52 KiB). MXU: unused.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pallas_call


def _make_kernel(stripe: int, width: int):
    def kernel(p_ref, o_ref):
        p = p_ref[0]  # (stripe + 1, width + 1, 3) halo panel

        def tap(dy, dx):
            return jax.lax.dynamic_slice(p, (dy, dx, 0), (stripe, width, 3))

        du = tap(1, 0) - tap(0, 0)
        dv = tap(0, 1) - tap(0, 0)
        n = jnp.cross(du, dv)
        norm = jnp.sqrt((n * n).sum(-1, keepdims=True))
        o_ref[...] = n / jnp.maximum(norm, 1e-8)

    return kernel


def normal_est(points, *, stripe: int = 32):
    """Normals of an (H, W, 3) point grid (edge-clamped differences)."""
    h, w, _ = points.shape
    if h % stripe:
        raise ValueError(f"normal_est: H={h} not a multiple of {stripe}")
    # Edge-clamp pad so diff at the last row/col sees its own value
    # (matches ref.normal_est's append semantics).
    padded = jnp.concatenate([points, points[-1:, :, :]], axis=0)
    padded = jnp.concatenate([padded, padded[:, -1:, :]], axis=1)
    stack = _halo_stack(padded, stripe, h, w)
    grid = (cdiv(h, stripe),)
    return pallas_call(
        _make_kernel(stripe, w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, stripe + 1, w + 1, 3), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((stripe, w, 3), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, 3), jnp.float32),
    )(stack)


def _halo_stack(padded, stripe, h, w):
    """(grid, stripe+1, w+1, 3) stack of overlapping halo panels."""
    n = h // stripe
    starts = jnp.arange(n) * stripe
    return jax.vmap(
        lambda s: jax.lax.dynamic_slice(padded, (s, 0, 0), (stripe + 1, w + 1, 3))
    )(starts)
