"""AOT lowering driver: every accelerator variant -> HLO text + manifest.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Run via ``make artifacts`` (no-op when artifacts are newer than the
python sources). Python never runs on the request path — the Rust daemon
only ever reads ``artifacts/``.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model, specs

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant_name: str) -> str:
    fn, examples = model.build(variant_name)
    return to_hlo_text(jax.jit(fn).lower(*examples))


def manifest_entry(accel: "specs.AccelSpec") -> dict:
    return {
        "name": accel.name,
        "lang": accel.lang,
        "suite": accel.suite,
        "inputs": [{"shape": list(s), "dtype": "f32"} for s in accel.in_shapes],
        "outputs": [{"shape": list(s), "dtype": "f32"} for s in accel.out_shapes],
        "bytes_in": accel.bytes_in,
        "bytes_out": accel.bytes_out,
        # Listing-2/3 register map: control word at 0x00, then one 64-bit
        # operand pointer register every 8 bytes starting at 0x10.
        "registers": [{"name": "control", "offset": 0}]
        + [
            {"name": r, "offset": 16 + 8 * i}
            for i, r in enumerate(accel.registers)
        ],
        "variants": [
            {
                "name": v.name,
                "hlo": f"{v.name}.hlo.txt",
                "regions": v.regions,
                "cycles_per_item": v.cycles,
                "clock_hz": specs.CLOCK_HZ,
                "netlist": {
                    "luts": v.netlist.luts,
                    "ffs": v.netlist.ffs,
                    "brams": v.netlist.brams,
                    "dsps": v.netlist.dsps,
                },
                "kernel_params": dict(v.kernel_params),
            }
            for v in accel.variants
        ],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact dir")
    ap.add_argument("--only", default=None, help="lower a single variant")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    names = [args.only] if args.only else model.all_variants()
    checksums = {}
    for vn in names:
        text = lower_variant(vn)
        path = os.path.join(args.out, f"{vn}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        checksums[f"{vn}.hlo.txt"] = hashlib.sha256(
            text.encode()
        ).hexdigest()
        print(f"  lowered {vn:24s} -> {path} ({len(text)} chars)")

    manifest = {
        "version": MANIFEST_VERSION,
        "clock_hz": specs.CLOCK_HZ,
        "accelerators": [manifest_entry(a) for a in specs.ACCELERATORS],
        "checksums": checksums,
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}: {len(manifest['accelerators'])} accelerators, "
          f"{len(checksums)} artifacts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
