"""Single source of truth for the FOS accelerator catalog.

Everything the Rust L3 layer needs to know about an accelerator flows from
here through ``artifacts/manifest.json``:

- HLO artifact names + I/O shapes per *implementation variant* (the
  paper's resource-elastic alternatives: a v2 occupies two adjacent PR
  regions and runs faster),
- the 100 MHz cycle model per work item (drives the virtual-time
  scheduler figures, Figs 19-22),
- the netlist resource spec (drives the PnR simulator, Table 3, and the
  region allocator),
- the Listing-2/3-style register map (drives the generic driver).

Netlist sizes are calibrated against one Ultra96 PR region
(17760 LUTs / 35520 FFs / 72 BRAM36 / 120 DSP48 — Table 1) so that the
Table 3 utilisations come out at the paper's 33% / 63% / 81%.
"""

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

# One Ultra96 PR region (Table 1).
REGION_LUTS = 17760
REGION_FFS = 35520
REGION_BRAMS = 72
REGION_DSPS = 120

CLOCK_HZ = 100_000_000  # all accelerators run at 100 MHz (paper §5.5)


@dataclass(frozen=True)
class Netlist:
    """Post-synthesis resource footprint of one implementation variant."""

    luts: int
    ffs: int
    brams: int
    dsps: int

    def util_of_regions(self, regions: int) -> float:
        return self.luts / (REGION_LUTS * regions)


@dataclass(frozen=True)
class Variant:
    """One implementation alternative of an accelerator.

    ``regions`` adjacent PR slots are combined to host it; ``cycles`` is
    the modelled 100 MHz latency for one work item (one tile / block of
    the data-parallel decomposition, §4.4.2).
    """

    name: str
    regions: int
    cycles: int
    netlist: Netlist
    kernel_params: Dict[str, int] = field(default_factory=dict)


@dataclass(frozen=True)
class AccelSpec:
    name: str
    lang: str  # "c" | "opencl" | "rtl" — the paper's heterogeneity story
    suite: str  # "spector" | "inhouse" | "listing2"
    in_shapes: List[Tuple[int, ...]]
    out_shapes: List[Tuple[int, ...]]
    registers: List[str]  # operand registers after the 0x00 control word
    variants: List[Variant]

    @property
    def bytes_in(self) -> int:
        return sum(4 * _prod(s) for s in self.in_shapes)

    @property
    def bytes_out(self) -> int:
        return sum(4 * _prod(s) for s in self.out_shapes)


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def _nl(frac_lut: float, regions: int = 1, dsp_frac: float = 0.3,
        bram_frac: float = 0.3) -> Netlist:
    """Netlist sized as a fraction of ``regions`` Ultra96 PR regions."""
    return Netlist(
        luts=int(REGION_LUTS * regions * frac_lut),
        ffs=int(REGION_FFS * regions * frac_lut * 0.9),
        brams=int(REGION_BRAMS * regions * bram_frac),
        dsps=int(REGION_DSPS * regions * dsp_frac),
    )


# DCT's 2-region variant is the paper's super-linear case: 3.55x for 2x
# resources (Fig 19) — more row buffers *and* a larger butterfly unroll.
DCT_SUPERLINEAR = 3.55

ACCELERATORS: List[AccelSpec] = [
    AccelSpec(
        name="vadd", lang="c", suite="listing2",
        in_shapes=[(4096,), (4096,)], out_shapes=[(4096,)],
        registers=["a_op", "b_op", "c_out"],
        variants=[
            Variant("vadd_v1", 1, 4096, _nl(0.08, 1, 0.02, 0.05),
                    {"block": 1024}),
            Variant("vadd_v2", 2, 2048, _nl(0.08, 2, 0.02, 0.05),
                    {"block": 2048}),
        ],
    ),
    AccelSpec(
        name="mm", lang="opencl", suite="spector",
        in_shapes=[(64, 64), (64, 64)], out_shapes=[(64, 64)],
        registers=["a_op", "b_op", "c_out"],
        variants=[
            Variant("mm_v1", 1, 81920, _nl(0.55, 1, 0.55, 0.45),
                    {"bm": 32, "bn": 32, "bk": 32}),
            Variant("mm_v2", 2, 40960, _nl(0.55, 2, 0.55, 0.45),
                    {"bm": 64, "bn": 64, "bk": 64}),
        ],
    ),
    AccelSpec(
        name="fir", lang="opencl", suite="spector",
        in_shapes=[(4111,), (16,)], out_shapes=[(4096,)],
        registers=["x_op", "taps_op", "y_out"],
        variants=[
            Variant("fir_v1", 1, 40960, _nl(0.30, 1, 0.55, 0.15),
                    {"block": 1024}),
            Variant("fir_v2", 2, 20480, _nl(0.30, 2, 0.55, 0.15),
                    {"block": 2048}),
        ],
    ),
    AccelSpec(
        name="histogram", lang="opencl", suite="spector",
        in_shapes=[(4096,)], out_shapes=[(256,)],
        registers=["x_op", "h_out"],
        variants=[
            Variant("histogram_v1", 1, 40960, _nl(0.40, 1, 0.05, 0.60),
                    {"block": 1024}),
            Variant("histogram_v2", 2, 20480, _nl(0.40, 2, 0.05, 0.60),
                    {"block": 2048}),
        ],
    ),
    AccelSpec(
        name="dct", lang="opencl", suite="spector",
        in_shapes=[(64, 64)], out_shapes=[(64, 64)],
        registers=["in_img", "out_img"],
        variants=[
            Variant("dct_v1", 1, 40960, _nl(0.50, 1, 0.60, 0.40),
                    {"stripe": 8}),
            Variant("dct_v2", 2, int(40960 / DCT_SUPERLINEAR),
                    _nl(0.85, 2, 0.80, 0.70), {"stripe": 32}),
        ],
    ),
    AccelSpec(
        name="sobel", lang="opencl", suite="inhouse",
        in_shapes=[(128, 128)], out_shapes=[(128, 128)],
        registers=["in_img", "out_img"],
        variants=[
            Variant("sobel_v1", 1, 16384, _nl(0.35, 1, 0.10, 0.45),
                    {"stripe": 32}),
            Variant("sobel_v2", 2, 8192, _nl(0.35, 2, 0.10, 0.45),
                    {"stripe": 64}),
        ],
    ),
    AccelSpec(
        name="normal_est", lang="opencl", suite="spector",
        in_shapes=[(64, 64, 3)], out_shapes=[(64, 64, 3)],
        registers=["in_pts", "out_norm"],
        variants=[
            Variant("normal_est_v1", 1, 81920, _nl(0.63, 1, 0.50, 0.50),
                    {"stripe": 32}),
            Variant("normal_est_v2", 2, 40960, _nl(0.63, 2, 0.50, 0.50),
                    {"stripe": 64}),
        ],
    ),
    AccelSpec(
        name="mandelbrot", lang="c", suite="inhouse",
        in_shapes=[(64, 64, 2)], out_shapes=[(64, 64)],
        registers=["in_coords", "out_cnt"],
        variants=[
            Variant("mandelbrot_v1", 1, 262144, _nl(0.60, 1, 0.80, 0.10),
                    {"stripe": 32}),
            Variant("mandelbrot_v2", 2, 131072, _nl(0.60, 2, 0.80, 0.10),
                    {"stripe": 64}),
        ],
    ),
    AccelSpec(
        name="black_scholes", lang="opencl", suite="inhouse",
        in_shapes=[(4096, 5)], out_shapes=[(4096, 2)],
        registers=["in_params", "out_prices"],
        variants=[
            Variant("black_scholes_v1", 1, 409600, _nl(0.81, 1, 0.70, 0.30),
                    {"block": 1024}),
            Variant("black_scholes_v2", 2, 204800, _nl(0.81, 2, 0.70, 0.30),
                    {"block": 2048}),
        ],
    ),
    AccelSpec(
        name="aes", lang="rtl", suite="inhouse",
        in_shapes=[(4096,)], out_shapes=[(4096,)],
        registers=["in_data", "out_data"],
        # RTL module: no HLS DSE, hence a single implementation (the
        # paper's Table 3 "sparse" 33% workload).
        variants=[
            Variant("aes_v1", 1, 4096, _nl(0.33, 1, 0.00, 0.15)),
        ],
    ),
]

BY_NAME: Dict[str, AccelSpec] = {a.name: a for a in ACCELERATORS}

# Table 3 compile workloads: (accelerator, paper's region utilisation).
TABLE3_WORKLOADS = [("aes", 0.33), ("normal_est", 0.63), ("black_scholes", 0.81)]
