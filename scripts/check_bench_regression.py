#!/usr/bin/env python3
"""Bench-regression gate for the deterministic scheduler benches.

Compares the freshly generated ``BENCH_<name>.json`` files (written by
``fig22_multitenant`` and ``fig23_cluster_scaling`` via
``fos::testutil::write_bench_json``) against the committed
``BENCH_BASELINE_<name>.json`` files at the repo root, and fails when
any ``mean_turnaround_ns`` leaf regresses by more than the threshold
(default 20%).

All compared numbers are *virtual-time* simulator outputs, so they are
bit-for-bit deterministic across machines: any drift past the threshold
is a real scheduling regression, never runner noise.

Wall-clock benches are different: their numbers vary by machine, so
they are gated against an absolute *floor* instead of a prior run (see
``FLOOR_BENCHES``).  ``perf_round_latency`` must sustain at least 1M
decisions/sec on a single shard (300k in smoke mode) — the hot-path
throughput budget from the scheduler-microperformance work.

Bootstrapping: a baseline file containing ``"bootstrap": true`` carries
no numbers yet.  Then:

- with ``--fallback-baseline-dir DIR`` (CI passes the Actions cache of
  the previous run's ``BENCH_<name>.json`` files), the gate compares
  against those instead — a rolling gate that is armed from the very
  second CI run even while the committed baselines are placeholders;
- otherwise the gate reports what it *would* compare and exits 0.

Either way, ``--write-armed-dir DIR`` emits ready-to-commit
``BENCH_BASELINE_<name>.json`` copies of the current results (CI
uploads them as the ``armed-baselines`` artifact — commit them to pin
the gate to fixed numbers).

Usage:
  check_bench_regression.py [--baseline-dir DIR] [--current-dir DIR]
                            [--fallback-baseline-dir DIR]
                            [--write-armed-dir DIR]
                            [--threshold PCT] [--update]
"""

import argparse
import json
import os
import shutil
import sys

BENCHES = ["fig22_multitenant", "fig23_cluster_scaling", "fig24_admission_throughput"]
GATED_KEY = "mean_turnaround_ns"

# Wall-clock throughput benches: machine-dependent numbers, gated
# against an absolute floor, never compared across runs.
# (bench, leaf key, full-mode floor, smoke-mode floor)
FLOOR_BENCHES = [
    ("perf_round_latency", "single_shard_decisions_per_sec", 1_000_000.0, 300_000.0),
    # The reactor transport must sustain 100k concurrent sessions in
    # the full sweep (20k in smoke mode)...
    ("fig25_connection_scaling", "sessions_sustained", 100_000.0, 20_000.0),
    # ...the N-shard plane must sustain at least as many sessions as a
    # single shard (sessions-based, so a starved runner can't flake
    # it)...
    ("fig25_connection_scaling", "nshard_vs_1shard_ratio", 1.0, 1.0),
    # ...at no less throughput than the thread-per-connection baseline
    # serving 1k (smoke allows 10% runner noise on the ratio).
    ("fig25_connection_scaling", "reactor_vs_thread_ratio", 1.0, 0.9),
    # Bandwidth partitioning must keep the latency-QoS tenant's p99
    # bounded next to a saturating streaming tenant (virtual-time ratio
    # equal-split/partitioned — deterministic; the 0.9 floor tolerates
    # scheduling-order shifts, not a broken partition model).
    ("fig26_bw_interference", "latency_p99_improvement", 0.9, 0.9),
]


def leaves(node, prefix=()):
    """Yield (path, number) for every numeric leaf."""
    if isinstance(node, dict):
        for k, v in sorted(node.items()):
            yield from leaves(v, prefix + (k,))
    elif isinstance(node, list):
        for idx, v in enumerate(node):
            yield from leaves(v, prefix + (str(idx),))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        yield prefix, float(node)


def gated_leaves(doc):
    return {p: v for p, v in leaves(doc) if GATED_KEY in p}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", default=".")
    ap.add_argument("--current-dir", default=".")
    ap.add_argument("--fallback-baseline-dir", default=None,
                    help="previous run's BENCH_<name>.json files; used as the "
                         "baseline when the committed one is a bootstrap placeholder")
    ap.add_argument("--write-armed-dir", default=None,
                    help="also write ready-to-commit BENCH_BASELINE_<name>.json "
                         "copies of the current results into this directory")
    ap.add_argument("--threshold", type=float, default=20.0,
                    help="max allowed regression in percent (default 20)")
    ap.add_argument("--update", action="store_true",
                    help="copy current results over the baselines instead of gating")
    args = ap.parse_args()

    failures = []
    for bench in BENCHES:
        cur_path = os.path.join(args.current_dir, f"BENCH_{bench}.json")
        base_path = os.path.join(args.baseline_dir, f"BENCH_BASELINE_{bench}.json")
        if not os.path.exists(cur_path):
            failures.append(f"{bench}: missing current result {cur_path} "
                            "(did the bench run with FOS_BENCH_JSON_DIR set?)")
            continue
        with open(cur_path) as f:
            cur = json.load(f)

        if args.write_armed_dir:
            os.makedirs(args.write_armed_dir, exist_ok=True)
            armed = os.path.join(args.write_armed_dir, f"BENCH_BASELINE_{bench}.json")
            shutil.copyfile(cur_path, armed)
            print(f"{bench}: armed baseline written to {armed}")

        if args.update:
            shutil.copyfile(cur_path, base_path)
            print(f"{bench}: baseline updated from {cur_path}")
            continue

        if not os.path.exists(base_path):
            failures.append(f"{bench}: missing baseline {base_path}")
            continue
        with open(base_path) as f:
            base = json.load(f)

        if base.get("bootstrap"):
            fallback = (os.path.join(args.fallback_baseline_dir, f"BENCH_{bench}.json")
                        if args.fallback_baseline_dir else None)
            if fallback and os.path.exists(fallback):
                # Rolling gate: the committed baseline is a placeholder,
                # so compare against the previous CI run's deterministic
                # numbers instead of skipping the check entirely.
                with open(fallback) as f:
                    base = json.load(f)
                print(f"{bench}: committed baseline is bootstrap — "
                      f"gating against previous run ({fallback})")
            else:
                print(f"{bench}: baseline is a bootstrap placeholder — gate not armed.")
                print(f"  To arm it: commit the armed-baselines artifact as {base_path} "
                      "(or rerun this script with --update).")
                for path, v in sorted(gated_leaves(cur).items()):
                    print(f"  would gate {'.'.join(path)} = {v:.0f}")
                continue

        if base.get("smoke") != cur.get("smoke"):
            failures.append(
                f"{bench}: smoke-mode mismatch (baseline smoke={base.get('smoke')}, "
                f"current smoke={cur.get('smoke')}) — numbers are not comparable")
            continue

        base_l, cur_l = gated_leaves(base), gated_leaves(cur)
        if not base_l:
            failures.append(f"{bench}: baseline has no {GATED_KEY} leaves")
            continue
        for path, base_v in sorted(base_l.items()):
            name = ".".join(path)
            if path not in cur_l:
                failures.append(f"{bench}: {name} missing from current result")
                continue
            cur_v = cur_l[path]
            if base_v > 0 and cur_v > base_v * (1.0 + args.threshold / 100.0):
                pct = 100.0 * (cur_v / base_v - 1.0)
                failures.append(
                    f"{bench}: {name} regressed {pct:.1f}% "
                    f"({base_v:.0f} -> {cur_v:.0f}, threshold {args.threshold:.0f}%)")
            else:
                delta = 0.0 if base_v == 0 else 100.0 * (cur_v / base_v - 1.0)
                print(f"{bench}: {name} ok ({base_v:.0f} -> {cur_v:.0f}, {delta:+.1f}%)")

    for bench, key, full_floor, smoke_floor in FLOOR_BENCHES:
        cur_path = os.path.join(args.current_dir, f"BENCH_{bench}.json")
        if not os.path.exists(cur_path):
            failures.append(f"{bench}: missing current result {cur_path} "
                            "(did the bench run with FOS_BENCH_JSON_DIR set?)")
            continue
        with open(cur_path) as f:
            cur = json.load(f)
        smoke = bool(cur.get("smoke"))
        floor = smoke_floor if smoke else full_floor
        v = cur.get(key)
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            failures.append(f"{bench}: {key} missing from current result")
            continue
        if float(v) < floor:
            failures.append(
                f"{bench}: {key} = {float(v):.0f} below the "
                f"{'smoke' if smoke else 'full'}-mode floor {floor:.0f}")
        else:
            print(f"{bench}: {key} ok ({float(v):.0f} >= floor {floor:.0f}, "
                  f"{'smoke' if smoke else 'full'} mode)")

    if failures:
        print("\nBENCH REGRESSION GATE FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("bench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
