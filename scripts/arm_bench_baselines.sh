#!/usr/bin/env bash
# Arm the committed bench baselines in one shot: run the gated benches
# with deterministic smoke iterations (the same mode CI gates in),
# write their BENCH_<name>.json results at the repo root, and copy them
# over the committed BENCH_BASELINE_<name>.json placeholders.
#
# Run this once on a machine with a Rust toolchain, then commit the
# rewritten BENCH_BASELINE_*.json files — the regression gate switches
# from the rolling previous-run comparison to the pinned numbers.
# Floor-gated benches (perf_round_latency, fig25_connection_scaling,
# fig26_bw_interference) need no baseline; they are still run so the
# floor checks exercise a real result (fig25 sweeps both the 1-shard
# and N-shard reactor and emits sessions_sustained plus
# nshard_vs_1shard_ratio, all floor-gated).
#
# Also (re)arms the golden decision-trace fixture
# (rust/tests/fixtures/golden_decisions.txt): it self-arms on the first
# `cargo test` run, and FOS_UPDATE_GOLDEN=1 regenerates it after an
# intentional scheduling change.
set -euo pipefail
cd "$(dirname "$0")/.."

export FOS_BENCH_SMOKE=1
export FOS_BENCH_JSON_DIR="$PWD"

for b in fig22_multitenant fig23_cluster_scaling fig24_admission_throughput \
         perf_round_latency fig25_connection_scaling fig26_bw_interference; do
    echo "== $b =="
    cargo bench --manifest-path rust/Cargo.toml --bench "$b"
done

python3 scripts/check_bench_regression.py --baseline-dir . --current-dir . --update
python3 scripts/check_bench_regression.py --baseline-dir . --current-dir .
echo "baselines armed — commit the BENCH_BASELINE_*.json files"

echo "== golden decision fixture =="
FOS_UPDATE_GOLDEN=1 cargo test --manifest-path rust/Cargo.toml \
    --test golden_decisions -q
echo "fixture armed — commit rust/tests/fixtures/golden_decisions.txt"

# The canonical diurnal scenario replay (the scenario engine's golden
# gate) self-arms the same way; FOS_UPDATE_GOLDEN=1 regenerates it
# after an intentional scheduling or generator change.
echo "== golden scenario fixture =="
FOS_UPDATE_GOLDEN=1 cargo test --manifest-path rust/Cargo.toml \
    --test fuzz_orderings golden_scenario_fixture_matches -q
echo "fixture armed — commit rust/tests/fixtures/golden_scenario.txt"
