#!/usr/bin/env bash
# Arm the committed bench baselines in one shot: run the gated benches
# with deterministic smoke iterations (the same mode CI gates in),
# write their BENCH_<name>.json results at the repo root, and copy them
# over the committed BENCH_BASELINE_<name>.json placeholders.
#
# Run this once on a machine with a Rust toolchain, then commit the
# rewritten BENCH_BASELINE_*.json files — the regression gate switches
# from the rolling previous-run comparison to the pinned numbers.
# Floor-gated benches (perf_round_latency) need no baseline; they are
# still run so the floor check exercises a real result.
set -euo pipefail
cd "$(dirname "$0")/.."

export FOS_BENCH_SMOKE=1
export FOS_BENCH_JSON_DIR="$PWD"

for b in fig22_multitenant fig23_cluster_scaling fig24_admission_throughput \
         perf_round_latency; do
    echo "== $b =="
    cargo bench --manifest-path rust/Cargo.toml --bench "$b"
done

python3 scripts/check_bench_regression.py --baseline-dir . --current-dir . --update
python3 scripts/check_bench_regression.py --baseline-dir . --current-dir .
echo "baselines armed — commit the BENCH_BASELINE_*.json files"
