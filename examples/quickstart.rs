//! Quickstart (mode 1/2): single-tenant acceleration through the Cynq
//! library — load a shell, load `vadd` by logical name, program its
//! registers with the generic driver, run real compute via PJRT.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use fos::accel::Catalog;
use fos::driver::Cynq;
use fos::shell::ShellBoard;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let catalog = Catalog::load_default()?;
    println!("catalog: {:?}", catalog.names());

    // Bring up the Ultra96 shell (loads the full static bitstream).
    let mut fpga = Cynq::open(ShellBoard::Ultra96, catalog)?;
    println!(
        "shell {} up: {} PR regions, {} free",
        fpga.shell.name,
        fpga.shell.region_count(),
        fpga.free_regions()
    );

    // Contiguous device-visible buffers (the data manager).
    let n = 4096;
    let a = fpga.alloc(4 * n)?;
    let b = fpga.alloc(4 * n)?;
    let c = fpga.alloc(4 * n)?;
    let xs: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let ys: Vec<f32> = (0..n).map(|i| 2.0 * i as f32).collect();
    fpga.write_f32(a, &xs)?;
    fpga.write_f32(b, &ys)?;

    // Load by logical name; FOS picks the best implementation variant
    // for the free regions and relocates its partial bitstream.
    let (h, reconfig_latency) = fpga.load_accelerator("vadd", None)?;
    println!(
        "loaded vadd as {:?} (partial reconfiguration took {:.2} ms, modelled)",
        fpga.variant_of(h).unwrap(),
        reconfig_latency.as_secs_f64() * 1e3
    );

    // Generic driver: program registers by name, start, wait.
    fpga.write_reg(h, "a_op", a)?;
    fpga.write_reg(h, "b_op", b)?;
    fpga.write_reg(h, "c_out", c)?;
    let busy = fpga.run(h)?;
    println!("vadd ran: modelled FPGA latency {:.1} us", busy.as_secs_f64() * 1e6);

    let out = fpga.read_f32(c, n)?;
    for k in 0..n {
        assert_eq!(out[k], 3.0 * k as f32);
    }
    println!("verified {n} results: c[k] == 3k. quickstart OK");
    Ok(())
}
