//! The decoupled compilation flow, end to end (§4.1, Table 3):
//! synthesise a module netlist, place & route it inside a fenced PR
//! wrapper, write the full bitstream, BitMan-extract the relocatable
//! partial, relocate it to every region, and compare with the Xilinx
//! per-region flow.
//!
//! ```bash
//! cargo run --release --example compile_flow
//! ```

use fos::bitstream::relocate;
use fos::fabric::{Device, DeviceKind, Floorplan, Resources};
use fos::pnr::{compile_fos, compile_xilinx_pr, CostModel, Netlist};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
    println!(
        "Ultra96 floorplan: {} PR regions, legality violations: {:?}",
        fp.regions.len(),
        fp.check()
    );

    // The Black-Scholes module: 81% of one region (Table 3's densest).
    let netlist = Netlist::synthesize(
        "black_scholes",
        &Resources { luts: 14385, ffs: 25893, brams: 50, dsps: 36 },
    );
    println!(
        "synthesised netlist: {} cells, {} nets, {} interface nets",
        netlist.cells.len(),
        netlist.nets.len(),
        netlist.interface_cells.len()
    );

    let model = CostModel::default();
    let fos = compile_fos(&fp, &netlist, &model)?;
    println!(
        "\nFOS flow:    P&R {:.1} s + bitgen {:.1} s = {:.1} s (modelled Vivado), {} relocatable partial",
        fos.pnr_seconds,
        fos.bitgen_seconds,
        fos.total_seconds(),
        fos.partials.len()
    );
    println!(
        "  (simulator wallclock: {:?}, routed wirelength {}, {} congestion passes)",
        fos.sim_wallclock, fos.route_stats.wirelength, fos.route_stats.passes
    );

    let xil = compile_xilinx_pr(&fp, &netlist, &model)?;
    println!(
        "Xilinx flow: P&R {:.1} s + bitgen {:.1} s = {:.1} s, {} per-region partials",
        xil.pnr_seconds,
        xil.bitgen_seconds,
        xil.total_seconds(),
        xil.partials.len()
    );
    println!(
        "speedup: {:.2}x (paper Table 3: 2.34x for Black Scholes)",
        xil.total_seconds() / fos.total_seconds()
    );

    // Relocate the FOS partial to every region — the run-time half.
    let p0 = &fos.partials[0];
    for target in &fp.regions[1..] {
        let moved = relocate(&fp.device, p0, &fp.regions[0], target)?;
        println!(
            "relocated partial to {}: {} frames, {} KiB of config data",
            target.name,
            moved.frame_count(),
            moved.config_bytes() / 1024
        );
    }
    println!("compile_flow OK");
    Ok(())
}
