//! END-TO-END DRIVER (mode 3): a real daemon process arbitrating the
//! FPGA between concurrent tenants over the RPC + shared-memory path,
//! with every request computing real numbers through PJRT.
//!
//! Two tenants run concurrently — a C-language Mandelbrot app and an
//! OpenCL Sobel app (the paper's §5.5.2 pairing, demonstrating
//! mixed-language multi-tenancy) — each submitting frames chopped into
//! data-parallel requests. Reports per-tenant latency/throughput and
//! verifies numerics against CPU references. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example multi_tenant_daemon
//! ```

use fos::accel::Catalog;
use fos::daemon::{BufferHandle, Daemon, FpgaRpc, Job};
use fos::metrics::LatencyStats;
use fos::shell::ShellBoard;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let socket = std::env::temp_dir().join(format!("fos_e2e_{}.sock", std::process::id()));
    let catalog = Catalog::load_default()?;
    let daemon = Daemon::start(&socket, ShellBoard::Ultra96, catalog)?;
    println!("daemon up on {}", socket.display());

    let t0 = Instant::now();
    let mandel_sock = socket.clone();
    let mandel = std::thread::spawn(move || tenant_mandelbrot(&mandel_sock, 3, 4));
    let sobel_sock = socket.clone();
    let sobel = std::thread::spawn(move || tenant_sobel(&sobel_sock, 3, 4));

    let (m_stats, m_checked) = mandel.join().unwrap();
    let (s_stats, s_checked) = sobel.join().unwrap();
    let wall = t0.elapsed();

    println!("\n== multi-tenant end-to-end report ==");
    println!("wallclock: {wall:?} for 2 tenants x 3 frames x 4 requests");
    println!("  mandelbrot (C):    {}", m_stats.summary("request latency"));
    println!("  sobel (OpenCL):    {}", s_stats.summary("request latency"));
    println!(
        "  verified pixels: mandelbrot {m_checked}, sobel {s_checked} (vs CPU reference)"
    );
    let st = daemon.stats();
    use std::sync::atomic::Ordering::Relaxed;
    println!(
        "  daemon: {} jobs, {} reconfig loads, {} reuse hits, {} skips, \
         {} replications ({} jobs on replicated instances), mean sched decision {:.1} us",
        st.jobs.load(Relaxed),
        st.reconfig_loads.load(Relaxed),
        st.reuse_hits.load(Relaxed),
        st.skips.load(Relaxed),
        st.replications.load(Relaxed),
        st.replicated_jobs.load(Relaxed),
        st.sched_ns.load(Relaxed) as f64 / st.sched_decisions.load(Relaxed).max(1) as f64 / 1e3,
    );
    // The dispatcher runs the same SchedCore as the simulator; its
    // ordered decision log shows the elastic choices it made live.
    let log = daemon.decision_log_tail(6);
    println!(
        "  decision log: {} placements (showing last {})",
        st.jobs.load(Relaxed),
        log.len()
    );
    for d in log.iter() {
        println!(
            "    user {} {}::{} @ pr{}..+{} {}{}",
            d.user,
            d.accel,
            d.variant,
            d.anchor,
            d.span,
            if d.reconfigure { "reconfigure" } else { "reuse" },
            if d.replicated { " (replica)" } else { "" },
        );
    }
    let total_jobs = st.jobs.load(Relaxed);
    println!(
        "  throughput: {:.1} requests/s (daemon-side, real PJRT compute)",
        total_jobs as f64 / wall.as_secs_f64()
    );
    println!("multi_tenant_daemon OK");
    Ok(())
}

/// Tenant A: Mandelbrot over a fixed window, one frame = `reqs` tiles.
fn tenant_mandelbrot(socket: &std::path::Path, frames: usize, reqs: usize) -> (LatencyStats, usize) {
    let mut rpc = FpgaRpc::connect(socket).unwrap();
    // The scheduling-policy knob: this tenant explicitly asks for the
    // resource-elastic policy (also the default); `Policy::Fixed`
    // would pin it to one static region instead.
    rpc.set_policy(fos::sched::Policy::Elastic).unwrap();
    let mut stats = LatencyStats::new();
    let mut checked = 0usize;
    // 64x64 coordinate tile spanning [-2, 1] x [-1.5, 1.5].
    let coords: Vec<f32> = (0..64 * 64)
        .flat_map(|k| {
            let (i, j) = (k / 64, k % 64);
            [
                -2.0 + 3.0 * i as f32 / 63.0,
                -1.5 + 3.0 * j as f32 / 63.0,
            ]
        })
        .collect();
    let input = rpc.alloc(coords.len() * 4).unwrap();
    rpc.write_f32(input, &coords).unwrap();
    let outputs: Vec<BufferHandle> =
        (0..reqs).map(|_| rpc.alloc(64 * 64 * 4).unwrap()).collect();
    for _ in 0..frames {
        let jobs: Vec<Job> = outputs
            .iter()
            .map(|&out| Job::new(
                "mandelbrot",
                vec![("in_coords".into(), input), ("out_cnt".into(), out)],
            ))
            .collect();
        let report = rpc.run(&jobs).unwrap();
        for us in report.latencies_us {
            stats.record_us(us);
        }
    }
    // Verify: c = 0 (center-ish pixel) never escapes -> count == 64.
    let out = rpc.read_f32(outputs[0], 64 * 64).unwrap();
    let center = {
        // coords index where re ~ 0, im ~ 0: i=42 (re≈0), j=31/32.
        let i = ((0.0f32 + 2.0) / 3.0 * 63.0).round() as usize;
        let j = ((0.0f32 + 1.5) / 3.0 * 63.0).round() as usize;
        out[i * 64 + j]
    };
    assert!(center >= 60.0, "interior point should not escape: {center}");
    checked += out.len();
    (stats, checked)
}

/// Tenant B: Sobel over random frames; verifies against a CPU Sobel.
fn tenant_sobel(socket: &std::path::Path, frames: usize, reqs: usize) -> (LatencyStats, usize) {
    let mut rpc = FpgaRpc::connect(socket).unwrap();
    let mut stats = LatencyStats::new();
    let mut rng = fos::testutil::Rng::new(7);
    let img: Vec<f32> = (0..128 * 128).map(|_| rng.normal()).collect();
    let input = rpc.alloc(img.len() * 4).unwrap();
    rpc.write_f32(input, &img).unwrap();
    let outputs: Vec<BufferHandle> =
        (0..reqs).map(|_| rpc.alloc(128 * 128 * 4).unwrap()).collect();
    for _ in 0..frames {
        let jobs: Vec<Job> = outputs
            .iter()
            .map(|&out| Job::new(
                "sobel",
                vec![("in_img".into(), input), ("out_img".into(), out)],
            ))
            .collect();
        let report = rpc.run(&jobs).unwrap();
        for us in report.latencies_us {
            stats.record_us(us);
        }
    }
    let out = rpc.read_f32(outputs[reqs - 1], 128 * 128).unwrap();
    // CPU reference on a few interior pixels.
    let mut checked = 0usize;
    let at = |r: i64, c: i64| -> f32 {
        if (0..128).contains(&r) && (0..128).contains(&c) {
            img[(r * 128 + c) as usize]
        } else {
            0.0
        }
    };
    for &(r, c) in &[(1i64, 1i64), (64, 64), (126, 100), (30, 5)] {
        let gx = at(r - 1, c - 1) - at(r - 1, c + 1)
            + 2.0 * (at(r, c - 1) - at(r, c + 1))
            + at(r + 1, c - 1) - at(r + 1, c + 1);
        let gy = at(r - 1, c - 1) - at(r + 1, c - 1)
            + 2.0 * (at(r - 1, c) - at(r + 1, c))
            + at(r - 1, c + 1) - at(r + 1, c + 1);
        let want = (gx * gx + gy * gy).sqrt();
        let got = out[(r * 128 + c) as usize];
        assert!((got - want).abs() < 1e-3, "({r},{c}): {got} vs {want}");
        checked += 1;
    }
    (stats, checked)
}
