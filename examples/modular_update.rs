//! Modularity demo (§5.4, Table 5): swap system components at run time
//! without recompiling anything else.
//!
//! 1. swap an accelerator implementation under the same logical name
//!    (only a partial reconfiguration);
//! 2. swap the whole shell (full bitstream, drivers untouched);
//! 3. update the registry descriptor (no kernel/driver rebuild).
//!
//! ```bash
//! cargo run --release --example modular_update
//! ```

use fos::accel::Catalog;
use fos::driver::Cynq;
use fos::json::s;
use fos::registry::{accel_descriptor, Registry};
use fos::shell::{Shell, ShellBoard};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let catalog = Catalog::load_default()?;

    // --- 1. accelerator swap ------------------------------------------
    let mut fpga = Cynq::open(ShellBoard::Ultra96, catalog.clone())?;
    let (h1, lat1) = fpga.load_accelerator("sobel", Some("sobel_v1"))?;
    println!(
        "loaded sobel_v1 ({}): {:.2} ms partial reconfiguration",
        fpga.variant_of(h1).unwrap(),
        lat1.as_secs_f64() * 1e3
    );
    fpga.unload(h1)?;
    let (h2, lat2) = fpga.load_accelerator("sobel", Some("sobel_v2"))?;
    println!(
        "swapped to sobel_v2 ({}): {:.2} ms — same driver, same API, zero recompilation",
        fpga.variant_of(h2).unwrap(),
        lat2.as_secs_f64() * 1e3
    );

    // --- 2. shell swap -------------------------------------------------
    let full = fos::bitstream::synth_full(&fpga.shell.floorplan.device, 0xBEEF);
    let shell_lat = fpga.manager.load_full(full);
    println!(
        "shell update (full bitstream): {:.2} ms — paper Table 5: 20.74 ms on Ultra96",
        shell_lat.as_secs_f64() * 1e3
    );

    // --- 3. registry update --------------------------------------------
    let shell = Shell::build(ShellBoard::Ultra96);
    let mut reg = Registry::populate(&shell, &catalog)?;
    let mut desc = accel_descriptor(&shell, catalog.get("sobel").unwrap());
    if let fos::json::Value::Object(o) = &mut desc {
        o.insert("version".into(), s("2.0-improved"));
    }
    reg.update_accel(desc)?;
    println!(
        "registry updated: sobel now {}",
        reg.accel("sobel")?.get("version")
    );

    // --- Table 5 summary -------------------------------------------------
    println!("\ncomponent-update latencies (modelled, vs paper Table 5):");
    println!(
        "  accelerator: {:.2} ms 1-region swap (paper 3.81 ms, U96); {:.2} ms for the 2-region v2",
        lat1.as_secs_f64() * 1e3,
        lat2.as_secs_f64() * 1e3
    );
    println!("  shell:       {:.2} ms (paper 20.74 ms, U96)", shell_lat.as_secs_f64() * 1e3);
    println!("  runtime:     {:.1} ms (paper 15.2 ms)", fos::reconfig::RUNTIME_RESTART.as_secs_f64() * 1e3);
    println!("  kernel:      {:.0} s (paper 66 s, U96 with I/O bring-up)", fos::reconfig::KERNEL_REBOOT_U96.as_secs_f64());
    println!("modular_update OK");
    Ok(())
}
