//! Mode 2 + resource elasticity (§4.4, Fig 19-21 mechanics): a single
//! tenant exposes varying degrees of parallelism; the scheduler
//! replicates modules across PR regions, switches to bigger
//! implementations when slots are free, and time-multiplexes beyond.
//!
//! ```bash
//! cargo run --release --example elastic_single_tenant
//! ```

use fos::accel::Catalog;
use fos::metrics::Table;
use fos::sched::{simulate, JobSpec, Policy, SimConfig, Workload};
use fos::shell::ShellBoard;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let catalog = Catalog::load_default()?;

    // A 512x512 Sobel frame = 16 tiles of 128x128, exposed as 1..9
    // requests on the 3-region Ultra96.
    println!("sobel frame (16 tiles) on Ultra96, elastic scheduling:");
    let mut t = Table::new(
        "execution latency vs exposed parallelism",
        &["requests", "makespan (ms)", "speedup", "reconfigs", "reuses"],
    );
    let mut base = None;
    for requests in [1usize, 2, 3, 4, 6, 8, 9] {
        let mut w = Workload::new();
        for j in JobSpec::frame_pinned(0, "sobel", "sobel_v1", 0, 16, requests) {
            w.push(j);
        }
        let r = simulate(
            &catalog,
            &w,
            &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic),
        );
        let ms = r.makespan as f64 / 1e6;
        let b = *base.get_or_insert(ms);
        t.row(&[
            requests.to_string(),
            format!("{ms:.2}"),
            format!("{:.2}x", b / ms),
            r.counters.reconfigs.to_string(),
            r.counters.reuses.to_string(),
        ]);
    }
    t.print();

    // Replacement: DCT alone on ZCU102 gets its 2-region super-linear
    // implementation automatically.
    let mut w = Workload::new();
    for j in JobSpec::frame(0, "dct", 0, 240, 4) {
        w.push(j);
    }
    let r = simulate(
        &catalog,
        &w,
        &SimConfig::new(ShellBoard::Zcu102, Policy::Elastic),
    );
    let variants: std::collections::BTreeSet<String> =
        r.trace.iter().map(|t| t.variant.clone()).collect();
    println!("\nDCT single-tenant on ZCU102 picked variants: {variants:?}");
    println!("(dct_v2 = the 2-region, 3.55x super-linear implementation)");
    assert!(variants.contains("dct_v2"));
    println!("elastic_single_tenant OK");
    Ok(())
}
