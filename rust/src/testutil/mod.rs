//! Property-testing helpers (the offline vendor set has no proptest).
//!
//! A deterministic SplitMix64 generator plus a tiny `cases` driver:
//! every property runs over `n` seeded cases and reports the failing
//! seed, so failures reproduce exactly.

/// True when the PJRT backend can actually execute accelerator compute
/// — false under the offline binding stub (`runtime/pjrt_stub.rs`) or
/// when the `artifacts/` manifest is missing. Compute-dependent tests
/// call this and skip gracefully instead of failing the tier-1 gate;
/// scheduling, latency-model and protocol behaviour stay fully tested
/// either way.
pub fn pjrt_available() -> bool {
    use std::sync::OnceLock;
    static AVAILABLE: OnceLock<bool> = OnceLock::new();
    *AVAILABLE.get_or_init(|| {
        let Ok(catalog) = crate::accel::Catalog::load_default() else {
            return false;
        };
        let exec = crate::runtime::Executor::new(catalog);
        let ok = exec
            .execute("vadd_v1", vec![vec![0.0; 4096], vec![0.0; 4096]])
            .is_ok();
        exec.stop();
        ok
    })
}

/// Short-iteration mode for the CI `bench-smoke` job: `FOS_BENCH_SMOKE=1`
/// shrinks bench iteration counts so all 14 measurement programs run in
/// seconds (numbers are then indicative only — the job guards against
/// bit-rot, not regressions).
pub fn bench_smoke() -> bool {
    std::env::var("FOS_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// `full` iterations normally, `smoke` under `FOS_BENCH_SMOKE=1`.
pub fn bench_scale(full: usize, smoke: usize) -> usize {
    if bench_smoke() {
        smoke
    } else {
        full
    }
}

/// Case count for a property test: `default`, unless the
/// `FOS_PROPTEST_CASES` env knob overrides it (the nightly CI job sets
/// it to run every property at long iteration counts; a PROPTEST_CASES
/// -style absolute count, not a multiplier).
pub fn prop_cases(default: u64) -> u64 {
    std::env::var("FOS_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// Scenario-engine override for the measurement programs:
/// `FOS_SCENARIO=<spec>` replaces a bench's built-in workload with the
/// parsed trace (see `fos::sched::Scenario::parse`), so any recorded or
/// generated scenario replays through the benches exactly as it does
/// through `simulate`/`simulate_cluster` and the `--scenario` daemon.
/// A malformed spec is reported and ignored rather than silently
/// changing what the bench measured.
pub fn scenario_override() -> Option<crate::sched::Scenario> {
    let spec = std::env::var("FOS_SCENARIO").ok().filter(|s| !s.is_empty())?;
    match crate::sched::Scenario::parse(&spec) {
        Ok(sc) => Some(sc),
        Err(e) => {
            eprintln!("ignoring malformed FOS_SCENARIO ({e})");
            None
        }
    }
}

/// Write a bench's machine-readable result as `BENCH_<bench>.json` —
/// into `FOS_BENCH_JSON_DIR` when set (CI points it at the workspace
/// root so the regression gate and artifact upload find the files), or
/// the current directory otherwise.  Returns the path written.
pub fn write_bench_json(
    bench: &str,
    v: &crate::json::Value,
) -> std::io::Result<std::path::PathBuf> {
    let dir = std::env::var("FOS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join(format!("BENCH_{bench}.json"));
    std::fs::write(&path, crate::json::to_string_pretty(v) + "\n")?;
    Ok(path)
}

/// Operand register values for one request of `accel`, with properly
/// sized buffers allocated through the daemon: the accelerator's
/// non-control registers in map order, zipped with its input then
/// output tensor specs (the same ordering `Cynq::run` DMAs by).
pub fn alloc_operand_params(
    rpc: &mut crate::daemon::FpgaRpc,
    catalog: &crate::accel::Catalog,
    accel: &str,
) -> Vec<(String, crate::daemon::BufferHandle)> {
    let a = catalog.get(accel).expect("unknown accelerator");
    a.registers
        .iter()
        .filter(|r| r.name != "control")
        .zip(a.inputs.iter().chain(a.outputs.iter()))
        .map(|(r, spec)| (r.name.clone(), rpc.alloc(spec.bytes()).unwrap()))
        .collect()
}

/// SplitMix64 — tiny, fast, good-enough statistical quality for test
/// data and simulated workload generation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free Lemire reduction is overkill here; modulo bias
        // is negligible for test-sized ranges.
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi)` (usize convenience).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard-normal-ish (Irwin–Hall sum of 12 — fine for test data).
    pub fn normal(&mut self) -> f32 {
        let s: f64 = (0..12).map(|_| self.f64()).sum();
        (s - 6.0) as f32
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Exponentially-distributed inter-arrival time with mean `mean`.
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }
}

/// Run a property over `n` seeded cases; panics with the failing seed.
pub fn cases(n: u64, mut prop: impl FnMut(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a: Vec<u64> = {
            let mut r = Rng::new(7);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(7);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let mut r2 = Rng::new(8);
        assert_ne!(a[0], r2.next_u64());
    }

    #[test]
    fn ranges_in_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.range(3, 10);
            assert!((3..10).contains(&x));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn exp_mean_roughly_right() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(5.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.2, "{mean}");
    }

    #[test]
    #[should_panic]
    fn cases_reports_failure() {
        let mut n = 0;
        cases(10, |_rng| {
            n += 1;
            assert!(n < 5, "deliberate failure at case {n}");
        });
    }
}
