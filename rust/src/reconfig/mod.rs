//! The FPGA-manager analog (§3, §5.4): full and partial reconfiguration
//! with PR decoupler discipline and the Table-5 latency model.
//!
//! ## Latency calibration
//!
//! Partial reconfiguration moves configuration frames through the PCAP;
//! the effective rates are fitted to Table 5:
//!
//! - partial: 152 MB/s — Ultra96 slot (0.561 MB) → 3.7 ms vs paper
//!   3.81 ms; ZCU102 slot (1.077 MB) → 7.1 ms vs 6.77 ms.
//! - full (shell swap, incl. driver teardown + clock reinit): 95 MB/s —
//!   Ultra96 (2.165 MB) → 22.8 ms vs 20.74 ms; ZCU102 (8.95 MB) →
//!   94.2 ms vs 98.4 ms.
//!
//! Runtime restart (15.2 ms on both boards) and kernel reboot
//! (Table 5's 66 s / 15.76 s) are constants of the software stack, kept
//! here so the Table 5 bench has one source of truth.

use crate::bitstream::{merge, Bitstream, BitmanError};
use crate::fabric::Device;
use std::fmt;
use std::time::Duration;

/// Effective PCAP throughput for partial bitstreams (MB/s).
pub const PCAP_PARTIAL_MBPS: f64 = 152.0;
/// Effective throughput for full shell swaps (MB/s) — includes decoupler
/// + clock + driver re-init work.
pub const PCAP_FULL_MBPS: f64 = 95.0;
/// Multi-tenant daemon restart (Table 5 "Runtime").
pub const RUNTIME_RESTART: Duration = Duration::from_micros(15_200);
/// Kernel reboot (Table 5 "Kernel"): Ultra96 with full I/O bring-up vs
/// ZCU102 headless.
pub const KERNEL_REBOOT_U96: Duration = Duration::from_secs(66);
pub const KERNEL_REBOOT_ZCU102: Duration = Duration::from_millis(15_760);

#[derive(Debug)]
pub enum ReconfigError {
    /// Decoupler must isolate the region before programming it.
    DecouplerEnabled { region: usize },
    Bitman(BitmanError),
    NoSuchRegion(usize),
}

impl fmt::Display for ReconfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReconfigError::DecouplerEnabled { region } => {
                write!(f, "region {region} still coupled to the static system")
            }
            ReconfigError::Bitman(e) => write!(f, "bitman: {e}"),
            ReconfigError::NoSuchRegion(r) => write!(f, "no PR region {r}"),
        }
    }
}

impl std::error::Error for ReconfigError {}

impl From<BitmanError> for ReconfigError {
    fn from(e: BitmanError) -> Self {
        ReconfigError::Bitman(e)
    }
}

/// The FPGA manager: owns the device's live configuration image and the
/// per-region PR decouplers.
pub struct FpgaManager {
    pub device: Device,
    /// Live full-device configuration (None until a shell is loaded).
    pub configuration: Option<Bitstream>,
    /// Decoupler state per PR region: true = decoupled (safe to program).
    pub decoupled: Vec<bool>,
    /// Accumulated modelled reconfiguration time.
    pub total_reconfig_time: Duration,
    pub partial_loads: u64,
    pub full_loads: u64,
}

impl FpgaManager {
    pub fn new(device: Device, regions: usize) -> FpgaManager {
        FpgaManager {
            device,
            configuration: None,
            decoupled: vec![false; regions],
            total_reconfig_time: Duration::ZERO,
            partial_loads: 0,
            full_loads: 0,
        }
    }

    /// Modelled latency to program a bitstream of `bytes` config bytes.
    pub fn latency_for(bytes: usize, partial: bool) -> Duration {
        let mbps = if partial { PCAP_PARTIAL_MBPS } else { PCAP_FULL_MBPS };
        Duration::from_secs_f64(bytes as f64 / (mbps * 1e6))
    }

    /// Load a full shell bitstream (mode-1 bring-up or shell swap).
    pub fn load_full(&mut self, bs: Bitstream) -> Duration {
        let lat = Self::latency_for(bs.config_bytes(), false);
        self.configuration = Some(bs);
        self.total_reconfig_time += lat;
        self.full_loads += 1;
        lat
    }

    pub fn set_decoupler(&mut self, region: usize, decoupled: bool) -> Result<(), ReconfigError> {
        let d = self
            .decoupled
            .get_mut(region)
            .ok_or(ReconfigError::NoSuchRegion(region))?;
        *d = decoupled;
        Ok(())
    }

    /// Program a partial bitstream into a region. The PR decoupler must
    /// be engaged first (the paper's shells include Xilinx PR Decouplers
    /// exactly for this), and is released after.
    pub fn load_partial(
        &mut self,
        region: usize,
        partial: &Bitstream,
    ) -> Result<Duration, ReconfigError> {
        if region >= self.decoupled.len() {
            return Err(ReconfigError::NoSuchRegion(region));
        }
        if !self.decoupled[region] {
            return Err(ReconfigError::DecouplerEnabled { region });
        }
        if let Some(cfg) = &mut self.configuration {
            merge(cfg, partial)?;
        }
        let lat = Self::latency_for(partial.config_bytes(), true);
        self.total_reconfig_time += lat;
        self.partial_loads += 1;
        self.decoupled[region] = false; // re-couple after programming
        Ok(lat)
    }

    /// Convenience: decouple, program, re-couple.
    pub fn reconfigure_region(
        &mut self,
        region: usize,
        partial: &Bitstream,
    ) -> Result<Duration, ReconfigError> {
        self.set_decoupler(region, true)?;
        self.load_partial(region, partial)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::{blank, extract, synth_full};
    use crate::fabric::{DeviceKind, Floorplan};

    fn setup() -> (Floorplan, FpgaManager, Bitstream) {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        let mgr = FpgaManager::new(fp.device.clone(), fp.regions.len());
        let full = synth_full(&fp.device, 1);
        (fp, mgr, full)
    }

    #[test]
    fn table5_partial_latency_ultra96() {
        let (fp, _, full) = setup();
        let partial = extract(&fp.device, &full, &fp.regions[0]).unwrap();
        let lat = FpgaManager::latency_for(partial.config_bytes(), true);
        let paper = 3.81e-3;
        let rel = (lat.as_secs_f64() - paper).abs() / paper;
        assert!(rel < 0.08, "partial latency {lat:?} vs paper 3.81ms");
    }

    #[test]
    fn table5_full_latency_both_boards() {
        let (_, _, full_u96) = setup();
        let lat = FpgaManager::latency_for(full_u96.config_bytes(), false);
        assert!((lat.as_secs_f64() - 20.74e-3).abs() / 20.74e-3 < 0.15, "{lat:?}");
        let fp9 = Floorplan::standard(Device::new(DeviceKind::Zu9eg));
        let full9 = synth_full(&fp9.device, 2);
        let lat9 = FpgaManager::latency_for(full9.config_bytes(), false);
        assert!((lat9.as_secs_f64() - 98.4e-3).abs() / 98.4e-3 < 0.15, "{lat9:?}");
    }

    #[test]
    fn decoupler_protocol_enforced() {
        let (fp, mut mgr, full) = setup();
        mgr.load_full(full.clone());
        let partial = extract(&fp.device, &full, &fp.regions[1]).unwrap();
        // Programming without decoupling is rejected.
        assert!(matches!(
            mgr.load_partial(1, &partial),
            Err(ReconfigError::DecouplerEnabled { region: 1 })
        ));
        mgr.set_decoupler(1, true).unwrap();
        mgr.load_partial(1, &partial).unwrap();
        // Decoupler re-engaged (cleared) automatically after programming.
        assert!(!mgr.decoupled[1]);
        assert_eq!(mgr.partial_loads, 1);
    }

    #[test]
    fn blanking_then_module_load() {
        let (fp, mut mgr, full) = setup();
        mgr.load_full(full.clone());
        let b = blank(&fp.device, &fp.regions[0]);
        mgr.reconfigure_region(0, &b).unwrap();
        let cfg = mgr.configuration.as_ref().unwrap();
        // Region-0 frames are now zero.
        for (addr, words) in &b.frames {
            assert_eq!(cfg.frames.get(addr).unwrap(), words);
        }
        let m = extract(&fp.device, &synth_full(&fp.device, 9), &fp.regions[0]).unwrap();
        mgr.reconfigure_region(0, &m).unwrap();
        let cfg = mgr.configuration.as_ref().unwrap();
        for (addr, words) in &m.frames {
            assert_eq!(cfg.frames.get(addr).unwrap(), words);
        }
        assert_eq!(mgr.partial_loads, 2);
        assert!(mgr.total_reconfig_time > Duration::ZERO);
    }

    #[test]
    fn bad_region_index() {
        let (_, mut mgr, _) = setup();
        assert!(matches!(mgr.set_decoupler(7, true), Err(ReconfigError::NoSuchRegion(7))));
    }
}
