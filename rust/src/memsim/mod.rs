//! DDR + AXI memory-system model (§5.3, Figs 17–18; feeds Fig 22).
//!
//! Reproduces the two memory behaviours the paper measures with the
//! Zynq memory evaluation kit [38]:
//!
//! 1. **Throughput vs burst size** per duplex AXI HP port: short bursts
//!    pay the AXI command + PS-interconnect round trip per transfer, so
//!    throughput climbs with burst length and saturates at either the
//!    port wire limit (ZCU102) or the outstanding-transaction limit
//!    (Ultra96's LPDDR4 path).
//! 2. **Sub-linear multi-port scaling**: concurrent masters interleave
//!    at the DDR controller, polluting open DRAM rows and multiplexing
//!    the controller queue — total bandwidth caps below the port sum
//!    (the paper's 8804 MB/s vs 4 x 3200 on ZCU102).
//!
//! Calibration targets (paper §5.3): Ultra96 ≈530 MB/s per direction,
//! ≈1060 MB/s per duplex port, ≈3187 MB/s all three ports (74% of the
//! LPDDR4 peak); ZCU102 ≈1600 per direction, 3200 per port, 8804 all
//! four ports. The calibration test asserts these within 12%.

mod model;

pub use model::{DdrModel, MemConfig, PortLoad, Throughput};

use crate::shell::ShellBoard;

/// Board-specific memory configuration.
pub fn config_for(board: ShellBoard) -> MemConfig {
    match board {
        // Ultra96/UltraZed: 32-bit LPDDR4 behind the PS. Long PS-DDR
        // round trip and a single outstanding transaction per HP port
        // keep a lone stream latency-bound well below the wire.
        ShellBoard::Ultra96 | ShellBoard::UltraZed => MemConfig {
            port_bits: 128,
            port_mhz: 100,
            max_outstanding: 1,
            round_trip_ns: 1292.0,
            dram_peak_mbps: 4280.0,
            row_pollution: 0.3064,
            ports: 3,
        },
        // ZCU102: 64-bit DDR4-2400 — each HP port is wire-limited, the
        // controller is the shared bottleneck under concurrency.
        ShellBoard::Zcu102 => MemConfig {
            port_bits: 128,
            port_mhz: 100,
            max_outstanding: 2,
            round_trip_ns: 400.0,
            dram_peak_mbps: 19200.0,
            row_pollution: 0.6188,
            ports: 4,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn near(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() / b < tol
    }

    #[test]
    fn ultra96_calibration_matches_paper() {
        let m = DdrModel::new(config_for(ShellBoard::Ultra96));
        let one = m.steady_state(&[PortLoad::duplex(1024)]);
        // ~530 MB/s per direction, ~1060 per duplex port.
        assert!(near(one.per_port_dir_mbps[0].0, 530.0, 0.12), "{one:?}");
        assert!(near(one.total_mbps, 1060.0, 0.12), "{one:?}");
        let all = m.steady_state(&[PortLoad::duplex(1024); 3]);
        assert!(near(all.total_mbps, 3187.0, 0.12), "{all:?}");
    }

    #[test]
    fn zcu102_calibration_matches_paper() {
        let m = DdrModel::new(config_for(ShellBoard::Zcu102));
        let one = m.steady_state(&[PortLoad::duplex(1024)]);
        assert!(near(one.per_port_dir_mbps[0].0, 1600.0, 0.12), "{one:?}");
        assert!(near(one.total_mbps, 3200.0, 0.12), "{one:?}");
        let all = m.steady_state(&[PortLoad::duplex(1024); 4]);
        assert!(near(all.total_mbps, 8804.0, 0.12), "{all:?}");
        // Sub-linear: 4 ports deliver well under 4x one port.
        assert!(all.total_mbps < 4.0 * one.total_mbps * 0.75);
    }
}
