//! The steady-state bandwidth model and the transfer-time API the
//! scheduler uses for accelerator DMA accounting.

/// Memory-system configuration for one board (see `config_for`).
#[derive(Debug, Clone, Copy)]
pub struct MemConfig {
    /// AXI HP port data width (bits) and clock.
    pub port_bits: u32,
    pub port_mhz: u32,
    /// Outstanding transactions the PS accepts per port per direction.
    pub max_outstanding: u32,
    /// Command-to-first-data round trip through the PS interconnect +
    /// controller queue (ns).
    pub round_trip_ns: f64,
    /// DRAM core peak bandwidth (MB/s).
    pub dram_peak_mbps: f64,
    /// Row-pollution severity: fraction of the DRAM peak lost to row
    /// misses when infinitely many masters interleave (0 = immune).
    pub row_pollution: f64,
    /// Number of HP ports the shell wires to PR regions.
    pub ports: usize,
}

impl MemConfig {
    /// Port wire limit per direction (MB/s).
    pub fn wire_mbps(&self) -> f64 {
        (self.port_bits as f64 / 8.0) * self.port_mhz as f64
    }
}

/// Traffic offered on one port.
#[derive(Debug, Clone, Copy)]
pub struct PortLoad {
    /// Burst length in bytes per AXI transaction.
    pub burst_bytes: u32,
    pub reads: bool,
    pub writes: bool,
}

impl PortLoad {
    pub fn duplex(burst_bytes: u32) -> PortLoad {
        PortLoad { burst_bytes, reads: true, writes: true }
    }

    pub fn read_only(burst_bytes: u32) -> PortLoad {
        PortLoad { burst_bytes, reads: true, writes: false }
    }

    fn directions(&self) -> usize {
        usize::from(self.reads) + usize::from(self.writes)
    }
}

/// Steady-state result.
#[derive(Debug, Clone)]
pub struct Throughput {
    /// (read, write) MB/s per port, in input order.
    pub per_port_dir_mbps: Vec<(f64, f64)>,
    pub total_mbps: f64,
    /// The binding constraint, for diagnostics.
    pub bound_by: Bound,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    PortWire,
    Outstanding,
    DramController,
}

/// The model.
#[derive(Debug, Clone)]
pub struct DdrModel {
    pub cfg: MemConfig,
}

impl DdrModel {
    pub fn new(cfg: MemConfig) -> DdrModel {
        DdrModel { cfg }
    }

    /// Per-direction demand of one stream at a burst size (MB/s):
    /// min(wire, outstanding-limited pipeline).
    fn stream_demand(&self, burst_bytes: u32) -> (f64, Bound) {
        let beats = (burst_bytes as f64 / (self.cfg.port_bits as f64 / 8.0)).max(1.0);
        let beat_ns = 1000.0 / self.cfg.port_mhz as f64;
        let xfer_ns = self.cfg.round_trip_ns + beats * beat_ns;
        let pipelined =
            self.cfg.max_outstanding as f64 * burst_bytes as f64 / xfer_ns * 1000.0; // MB/s
        let wire = self.cfg.wire_mbps();
        if pipelined < wire {
            (pipelined, Bound::Outstanding)
        } else {
            (wire, Bound::PortWire)
        }
    }

    /// DRAM effective ceiling with `k` concurrently active *masters*
    /// (directions): row-buffer hit rate decays as masters interleave.
    fn dram_ceiling(&self, k: usize) -> f64 {
        if k == 0 {
            return self.cfg.dram_peak_mbps;
        }
        let interleave = (k as f64 - 1.0) / k as f64; // 0 for 1 master
        self.cfg.dram_peak_mbps * (1.0 - self.cfg.row_pollution * interleave)
    }

    /// Steady-state throughput for a set of active port loads.
    pub fn steady_state(&self, loads: &[PortLoad]) -> Throughput {
        assert!(loads.len() <= self.cfg.ports, "more loads than HP ports");
        let mut demands: Vec<(f64, f64)> = Vec::with_capacity(loads.len());
        let mut total_demand = 0.0;
        let mut bound = Bound::PortWire;
        let mut masters = 0usize;
        for l in loads {
            let (d, b) = self.stream_demand(l.burst_bytes);
            let r = if l.reads { d } else { 0.0 };
            let w = if l.writes { d } else { 0.0 };
            demands.push((r, w));
            total_demand += r + w;
            masters += l.directions();
            if b == Bound::Outstanding {
                bound = Bound::Outstanding;
            }
        }
        let ceiling = self.dram_ceiling(masters);
        let scale = if total_demand > ceiling {
            bound = Bound::DramController;
            ceiling / total_demand
        } else {
            1.0
        };
        let per_port: Vec<(f64, f64)> =
            demands.iter().map(|&(r, w)| (r * scale, w * scale)).collect();
        Throughput {
            total_mbps: total_demand * scale,
            per_port_dir_mbps: per_port,
            bound_by: bound,
        }
    }

    /// Time (ns) to move `bytes` one way on one port while `concurrent`
    /// other masters are active — the scheduler's DMA cost function.
    /// Accelerator DMAs use long bursts (1 KiB). Unlike the memory
    /// evaluation kit's pure sequential streams (Figs 17–18),
    /// accelerator access patterns are strided/tiled, so concurrent
    /// masters conflict in the row buffers beyond the steady-state
    /// model: the paper attributes Fig 22's degradation to exactly this
    /// ("row-bank pollution"). We add 8% per concurrent master.
    pub fn transfer_ns(&self, bytes: usize, concurrent: usize) -> f64 {
        let loads: Vec<PortLoad> = std::iter::repeat(PortLoad::duplex(1024))
            .take((concurrent + 1).min(self.cfg.ports.max(1)))
            .collect();
        let t = self.steady_state(&loads);
        let pattern_pollution = 1.0 + 0.08 * concurrent.min(self.cfg.ports) as f64;
        let mbps = (t.per_port_dir_mbps[0].0 / pattern_pollution).max(1.0);
        bytes as f64 / (mbps * 1e6) * 1e9
    }

    /// [`DdrModel::transfer_ns`] under **weighted bandwidth
    /// partitioning** — the tenant-isolation QoS knob.
    ///
    /// Without partitioning the memory controller arbitrates per
    /// *master*: a streaming tenant running `k` concurrent DMA engines
    /// takes `k/(k+1)` of the aggregate and a latency tenant's single
    /// transfer degrades without bound as `k` grows. Partitioned, the
    /// aggregate bandwidth under the same contention is split per
    /// *tenant* in proportion to QoS `weight`, then evenly across that
    /// tenant's own active masters:
    ///
    /// ```text
    /// rate(master of T) = aggregate(k) * weight_T / active_weight / masters_T
    /// ```
    ///
    /// - `weight`: this tenant's QoS weight (≥ 1);
    /// - `active_weight`: sum of weights over all tenants with a
    ///   concurrently active master, including this one;
    /// - `tenant_masters`: how many of the `concurrent + 1` masters
    ///   belong to this tenant, including this transfer;
    /// - `concurrent`: other active masters fabric-wide, as in
    ///   [`DdrModel::transfer_ns`].
    ///
    /// The partition is **work-conserving**: when no other tenant has
    /// an active master (`active_weight <= weight`) the transfer runs
    /// at the unpartitioned contended rate — an idle tenant's
    /// entitlement is redistributed, never reserved. A tenant's share
    /// can cap its own rate below the equal split (that is the
    /// streaming tenant paying for its fan-out) but never pushes any
    /// transfer faster than the uncontended solo rate.
    pub fn transfer_ns_partitioned(
        &self,
        bytes: usize,
        weight: u32,
        active_weight: u32,
        tenant_masters: usize,
        concurrent: usize,
    ) -> f64 {
        let equal_ns = self.transfer_ns(bytes, concurrent);
        let w = f64::from(weight.max(1));
        let total = f64::from(active_weight.max(weight.max(1)));
        if concurrent == 0 || total <= w {
            // Sole active tenant: work-conserving, full contended rate
            // (contention can only be its own masters).
            return equal_ns;
        }
        let masters = (concurrent + 1) as f64;
        let own = tenant_masters.max(1).min(concurrent + 1) as f64;
        // equal_ns corresponds to a 1/masters share of the aggregate;
        // rescale to the weighted per-tenant share split across the
        // tenant's own masters, floored at the uncontended solo time.
        let weighted_ns = equal_ns * total * own / (w * masters);
        weighted_ns.max(self.transfer_ns(bytes, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::config_for;
    use crate::shell::ShellBoard;

    fn u96() -> DdrModel {
        DdrModel::new(config_for(ShellBoard::Ultra96))
    }

    fn zcu() -> DdrModel {
        DdrModel::new(config_for(ShellBoard::Zcu102))
    }

    #[test]
    fn throughput_rises_with_burst_size() {
        let m = u96();
        let mut prev = 0.0;
        for burst in [16u32, 64, 256, 1024] {
            let t = m.steady_state(&[PortLoad::duplex(burst)]);
            assert!(t.total_mbps > prev, "burst {burst}: {t:?}");
            prev = t.total_mbps;
        }
    }

    #[test]
    fn read_write_split_even() {
        let m = u96();
        let t = m.steady_state(&[PortLoad::duplex(1024)]);
        let (r, w) = t.per_port_dir_mbps[0];
        assert!((r - w).abs() < 1e-9, "paper: even read/write split");
    }

    #[test]
    fn read_only_halves_port_traffic() {
        let m = u96();
        let duplex = m.steady_state(&[PortLoad::duplex(1024)]);
        let ro = m.steady_state(&[PortLoad::read_only(1024)]);
        assert!((ro.total_mbps - duplex.total_mbps / 2.0).abs() < 1.0);
        assert_eq!(ro.per_port_dir_mbps[0].1, 0.0);
    }

    #[test]
    fn zcu102_port_is_wire_limited() {
        let m = zcu();
        let t = m.steady_state(&[PortLoad::duplex(4096)]);
        assert_eq!(t.bound_by, Bound::PortWire);
        assert!((t.per_port_dir_mbps[0].0 - m.cfg.wire_mbps()).abs() < 1e-6);
    }

    #[test]
    fn multi_port_binds_on_dram() {
        let m = zcu();
        let t = m.steady_state(&[PortLoad::duplex(1024); 4]);
        assert_eq!(t.bound_by, Bound::DramController);
        // Fair arbitration: all ports equal.
        let first = t.per_port_dir_mbps[0].0;
        assert!(t.per_port_dir_mbps.iter().all(|&(r, _)| (r - first).abs() < 1e-9));
    }

    #[test]
    fn transfer_time_scales_with_contention() {
        let m = u96();
        let solo = m.transfer_ns(65536, 0);
        let busy = m.transfer_ns(65536, 2);
        assert!(busy > solo, "{busy} vs {solo}");
        // 64 KiB at ~530 MB/s ≈ 124 us.
        assert!((solo / 1000.0 - 124.0).abs() < 20.0, "{solo}");
    }

    #[test]
    fn partitioned_share_shields_latency_tenant() {
        let m = u96();
        let bytes = 65536;
        // A streaming tenant (weight 1) drives 3 concurrent masters;
        // the latency tenant (weight 1) runs one transfer. Equal-split
        // arbitration gives the latency tenant 1/4 of the aggregate;
        // per-tenant partitioning gives it 1/2 — strictly faster.
        let unpartitioned = m.transfer_ns(bytes, 3);
        let partitioned = m.transfer_ns_partitioned(bytes, 1, 2, 1, 3);
        assert!(
            partitioned < unpartitioned,
            "partitioned {partitioned} must beat equal split {unpartitioned}"
        );
        // ...but never beats the uncontended solo rate.
        assert!(partitioned >= m.transfer_ns(bytes, 0));
        // The streaming tenant's own masters pay for the fan-out: each
        // of its 3 masters runs slower than the equal split.
        let stream = m.transfer_ns_partitioned(bytes, 1, 2, 3, 3);
        assert!(stream > unpartitioned, "{stream} vs {unpartitioned}");
    }

    #[test]
    fn partition_is_work_conserving_when_alone() {
        let m = u96();
        let bytes = 65536;
        // Sole active tenant: identical to the unpartitioned cost, both
        // uncontended and against its own masters.
        assert_eq!(m.transfer_ns_partitioned(bytes, 2, 2, 1, 0), m.transfer_ns(bytes, 0));
        assert_eq!(m.transfer_ns_partitioned(bytes, 2, 2, 3, 2), m.transfer_ns(bytes, 2));
        // Heavier weight buys a bigger share under contention.
        let heavy = m.transfer_ns_partitioned(bytes, 4, 5, 1, 3);
        let light = m.transfer_ns_partitioned(bytes, 1, 5, 1, 3);
        assert!(heavy < light, "{heavy} vs {light}");
    }

    #[test]
    #[should_panic]
    fn too_many_loads_rejected() {
        let m = u96();
        let _ = m.steady_state(&[PortLoad::duplex(64); 4]);
    }
}
