//! On-disk/in-memory bitstream format.
//!
//! Layout (little-endian):
//! ```text
//! magic "FOSB" | version u32 | device name len u32 + bytes |
//! kind u32 (0 = full, 1 = partial) | frame count u32 |
//! frames: [cr u32 | col u32 | minor u32 | FRAME_WORDS x u32] ... |
//! crc32 of everything above
//! ```

use std::collections::BTreeMap;
use std::fmt;

/// Words per configuration frame (UltraScale+ uses 93 x 32-bit words).
pub const FRAME_WORDS: usize = 93;

pub const MAGIC: &[u8; 4] = b"FOSB";
pub const VERSION: u32 = 1;

/// Frame address: the column segment of one clock region.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FrameAddr {
    pub clock_region: u32,
    pub column: u32,
    pub minor: u32,
}

/// One configuration frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub addr: FrameAddr,
    pub words: Vec<u32>,
}

impl Frame {
    pub fn new(addr: FrameAddr, words: Vec<u32>) -> Frame {
        assert_eq!(words.len(), FRAME_WORDS);
        Frame { addr, words }
    }

    pub fn zeroed(addr: FrameAddr) -> Frame {
        Frame { addr, words: vec![0; FRAME_WORDS] }
    }
}

/// A configuration bitstream: full-device or partial.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitstream {
    pub device: String,
    pub partial: bool,
    pub frames: BTreeMap<FrameAddr, Vec<u32>>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FormatError {
    BadMagic,
    BadVersion(u32),
    Truncated,
    CrcMismatch { want: u32, got: u32 },
    BadFrameSize,
}

impl fmt::Display for FormatError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FormatError::BadMagic => write!(f, "not a FOSB bitstream"),
            FormatError::BadVersion(v) => write!(f, "unsupported version {v}"),
            FormatError::Truncated => write!(f, "truncated bitstream"),
            FormatError::CrcMismatch { want, got } => {
                write!(f, "crc mismatch: want {want:#010x} got {got:#010x}")
            }
            FormatError::BadFrameSize => write!(f, "bad frame size"),
        }
    }
}

impl std::error::Error for FormatError {}

impl Bitstream {
    pub fn new(device: impl Into<String>, partial: bool) -> Bitstream {
        Bitstream { device: device.into(), partial, frames: BTreeMap::new() }
    }

    pub fn insert(&mut self, frame: Frame) {
        self.frames.insert(frame.addr, frame.words);
    }

    pub fn frame_count(&self) -> usize {
        self.frames.len()
    }

    /// Configuration payload size in bytes (drives reconfiguration
    /// latency: bytes / PCAP throughput).
    pub fn config_bytes(&self) -> usize {
        self.frames.len() * FRAME_WORDS * 4
    }

    /// Serialise with trailing CRC32.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + self.frames.len() * (12 + FRAME_WORDS * 4));
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        let name = self.device.as_bytes();
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name);
        out.extend_from_slice(&(self.partial as u32).to_le_bytes());
        out.extend_from_slice(&(self.frames.len() as u32).to_le_bytes());
        for (addr, words) in &self.frames {
            out.extend_from_slice(&addr.clock_region.to_le_bytes());
            out.extend_from_slice(&addr.column.to_le_bytes());
            out.extend_from_slice(&addr.minor.to_le_bytes());
            for w in words {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
        let crc = crc32fast::hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn from_bytes(data: &[u8]) -> Result<Bitstream, FormatError> {
        if data.len() < 4 + 4 + 4 {
            return Err(FormatError::Truncated);
        }
        let (body, crc_bytes) = data.split_at(data.len() - 4);
        let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        let got = crc32fast::hash(body);
        if want != got {
            return Err(FormatError::CrcMismatch { want, got });
        }
        let mut r = Reader { data: body, pos: 0 };
        if r.bytes(4)? != MAGIC {
            return Err(FormatError::BadMagic);
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(FormatError::BadVersion(version));
        }
        let name_len = r.u32()? as usize;
        let device = String::from_utf8_lossy(r.bytes(name_len)?).into_owned();
        let partial = r.u32()? != 0;
        let count = r.u32()? as usize;
        let mut frames = BTreeMap::new();
        for _ in 0..count {
            let addr = FrameAddr {
                clock_region: r.u32()?,
                column: r.u32()?,
                minor: r.u32()?,
            };
            let mut words = Vec::with_capacity(FRAME_WORDS);
            for _ in 0..FRAME_WORDS {
                words.push(r.u32()?);
            }
            frames.insert(addr, words);
        }
        if r.pos != body.len() {
            return Err(FormatError::Truncated);
        }
        Ok(Bitstream { device, partial, frames })
    }
}

struct Reader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8], FormatError> {
        if self.pos + n > self.data.len() {
            return Err(FormatError::Truncated);
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, FormatError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Bitstream {
        let mut bs = Bitstream::new("xczu3eg", false);
        for col in 0..4u32 {
            for minor in 0..3u32 {
                let addr = FrameAddr { clock_region: 1, column: col, minor };
                let words = (0..FRAME_WORDS as u32)
                    .map(|w| w ^ (col << 16) ^ minor)
                    .collect();
                bs.insert(Frame::new(addr, words));
            }
        }
        bs
    }

    #[test]
    fn roundtrip() {
        let bs = sample();
        let bytes = bs.to_bytes();
        let back = Bitstream::from_bytes(&bytes).unwrap();
        assert_eq!(back, bs);
    }

    #[test]
    fn crc_detects_bitflip() {
        let mut bytes = sample().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(matches!(
            Bitstream::from_bytes(&bytes),
            Err(FormatError::CrcMismatch { .. })
        ));
    }

    #[test]
    fn rejects_wrong_magic_and_truncation() {
        let bytes = sample().to_bytes();
        let mut bad = bytes.clone();
        bad[0] = b'X';
        // CRC catches it first (magic is covered by the CRC), so corrupt
        // the CRC to match... simpler: truncation.
        assert!(Bitstream::from_bytes(&bytes[..10]).is_err());
        assert!(Bitstream::from_bytes(&[]).is_err());
        assert!(Bitstream::from_bytes(&bad).is_err());
    }

    #[test]
    fn config_bytes() {
        let bs = sample();
        assert_eq!(bs.config_bytes(), 12 * FRAME_WORDS * 4);
    }

    #[test]
    fn frame_addr_ordering_is_deterministic() {
        let bs = sample();
        let addrs: Vec<_> = bs.frames.keys().copied().collect();
        let mut sorted = addrs.clone();
        sorted.sort();
        assert_eq!(addrs, sorted);
    }
}
