//! BitMan-analog operations: extract / relocate / blank / merge.

use super::format::{Bitstream, Frame, FrameAddr};
use crate::fabric::{Device, PrRegion, CLOCK_REGION_ROWS};
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BitmanError {
    /// Source/target footprints differ — relocation would misconfigure.
    FootprintMismatch { from: String, to: String },
    /// Region is not clock-region aligned.
    NotAligned(String),
    /// The full bitstream is missing frames the region should contain.
    MissingFrames { region: String, missing: usize },
    /// Device names disagree.
    DeviceMismatch { a: String, b: String },
    /// Merging a partial marked as full (or vice versa).
    KindMismatch,
}

impl fmt::Display for BitmanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BitmanError::FootprintMismatch { from, to } => {
                write!(f, "footprints of {from} and {to} differ")
            }
            BitmanError::NotAligned(r) => write!(f, "region {r} not clock-aligned"),
            BitmanError::MissingFrames { region, missing } => {
                write!(f, "{missing} frames missing for region {region}")
            }
            BitmanError::DeviceMismatch { a, b } => write!(f, "device {a} != {b}"),
            BitmanError::KindMismatch => write!(f, "full/partial kind mismatch"),
        }
    }
}

impl std::error::Error for BitmanError {}

/// Frame addresses covered by a PR region on a device.
pub fn region_frames(device: &Device, region: &PrRegion) -> Vec<FrameAddr> {
    let cr0 = (region.bbox.r0 / CLOCK_REGION_ROWS) as u32;
    let cr1 = (region.bbox.r1 / CLOCK_REGION_ROWS) as u32;
    let mut out = Vec::new();
    for cr in cr0..cr1 {
        for col in region.bbox.c0..region.bbox.c1 {
            let kind = device.columns[col];
            for minor in 0..kind.frames_per_region() as u32 {
                out.push(FrameAddr { clock_region: cr, column: col as u32, minor });
            }
        }
    }
    out
}

/// Extract the partial bitstream for `region` out of a full-device
/// bitstream (the FOS flow's post-Vivado step).
pub fn extract(
    device: &Device,
    full: &Bitstream,
    region: &PrRegion,
) -> Result<Bitstream, BitmanError> {
    if !region.is_clock_aligned() {
        return Err(BitmanError::NotAligned(region.name.clone()));
    }
    if full.device != device.kind.name() {
        return Err(BitmanError::DeviceMismatch {
            a: full.device.clone(),
            b: device.kind.name().to_string(),
        });
    }
    let mut partial = Bitstream::new(full.device.clone(), true);
    let mut missing = 0usize;
    for addr in region_frames(device, region) {
        match full.frames.get(&addr) {
            Some(words) => partial.insert(Frame::new(addr, words.clone())),
            None => missing += 1,
        }
    }
    if missing > 0 {
        return Err(BitmanError::MissingFrames { region: region.name.clone(), missing });
    }
    Ok(partial)
}

/// Relocate a partial bitstream from one region to another by rewriting
/// the clock-region field of every frame address. Legal only when the
/// footprints (column kinds + spans) are identical.
pub fn relocate(
    device: &Device,
    partial: &Bitstream,
    from: &PrRegion,
    to: &PrRegion,
) -> Result<Bitstream, BitmanError> {
    if from.footprint(device) != to.footprint(device)
        || from.bbox.rows() != to.bbox.rows()
        || from.tunnel_rows != to.tunnel_rows
    {
        return Err(BitmanError::FootprintMismatch {
            from: from.name.clone(),
            to: to.name.clone(),
        });
    }
    if !from.is_clock_aligned() || !to.is_clock_aligned() {
        return Err(BitmanError::NotAligned(from.name.clone()));
    }
    let cr_from = (from.bbox.r0 / CLOCK_REGION_ROWS) as i64;
    let cr_to = (to.bbox.r0 / CLOCK_REGION_ROWS) as i64;
    let col_delta = to.bbox.c0 as i64 - from.bbox.c0 as i64;
    let mut out = Bitstream::new(partial.device.clone(), true);
    for (addr, words) in &partial.frames {
        let new_addr = FrameAddr {
            clock_region: (addr.clock_region as i64 - cr_from + cr_to) as u32,
            column: (addr.column as i64 + col_delta) as u32,
            minor: addr.minor,
        };
        out.insert(Frame::new(new_addr, words.clone()));
    }
    Ok(out)
}

/// Blanking bitstream: zero frames for a region (the shell descriptor's
/// per-region `blank` file, Listing 1).
pub fn blank(device: &Device, region: &PrRegion) -> Bitstream {
    let mut bs = Bitstream::new(device.kind.name(), true);
    for addr in region_frames(device, region) {
        bs.insert(Frame::zeroed(addr));
    }
    bs
}

/// Merge a partial bitstream into a full configuration image (what the
/// configuration port does on partial reconfiguration).
pub fn merge(full: &mut Bitstream, partial: &Bitstream) -> Result<usize, BitmanError> {
    if full.partial || !partial.partial {
        return Err(BitmanError::KindMismatch);
    }
    if full.device != partial.device {
        return Err(BitmanError::DeviceMismatch {
            a: full.device.clone(),
            b: partial.device.clone(),
        });
    }
    for (addr, words) in &partial.frames {
        full.frames.insert(*addr, words.clone());
    }
    Ok(partial.frames.len())
}

/// Deterministic pseudo-content full-device bitstream for a design id —
/// what "Vivado writes a full static bitstream" reduces to in the
/// simulation. Same (device, design) always produces identical frames, so
/// extraction / relocation / merge are testable end-to-end.
pub fn synth_full(device: &Device, design: u64) -> Bitstream {
    use super::format::FRAME_WORDS;
    let mut bs = Bitstream::new(device.kind.name(), false);
    for cr in 0..device.clock_regions() as u32 {
        for (col, kind) in device.columns.iter().enumerate() {
            for minor in 0..kind.frames_per_region() as u32 {
                let addr = FrameAddr { clock_region: cr, column: col as u32, minor };
                let seed = design
                    ^ ((cr as u64) << 40)
                    ^ ((col as u64) << 20)
                    ^ minor as u64;
                let words = (0..FRAME_WORDS as u64)
                    .map(|w| {
                        let mut x = seed.wrapping_add(w.wrapping_mul(0x9E3779B97F4A7C15));
                        x ^= x >> 30;
                        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
                        (x >> 16) as u32
                    })
                    .collect();
                bs.insert(Frame::new(addr, words));
            }
        }
    }
    bs
}

/// Directly synthesise the partial bitstream a design would occupy in
/// `region` — identical frames to `extract(synth_full(..), region)` but
/// without materialising the other ~75% of the device. This is the
/// request-path variant (the scheduler loads modules with it); the
/// full-device version remains for shell builds. See EXPERIMENTS.md
/// §Perf for the measured effect on scheduling-decision latency.
pub fn synth_partial(device: &Device, region: &PrRegion, design: u64) -> Bitstream {
    use super::format::FRAME_WORDS;
    let mut bs = Bitstream::new(device.kind.name(), true);
    for addr in region_frames(device, region) {
        let seed = design
            ^ ((addr.clock_region as u64) << 40)
            ^ ((addr.column as u64) << 20)
            ^ addr.minor as u64;
        let words = (0..FRAME_WORDS as u64)
            .map(|w| {
                let mut x = seed.wrapping_add(w.wrapping_mul(0x9E3779B97F4A7C15));
                x ^= x >> 30;
                x = x.wrapping_mul(0xBF58476D1CE4E5B9);
                (x >> 16) as u32
            })
            .collect();
        bs.insert(Frame::new(addr, words));
    }
    bs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{DeviceKind, Floorplan};

    #[test]
    fn synth_partial_equals_extract_of_synth_full() {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        let full = synth_full(&fp.device, 1234);
        for region in &fp.regions {
            let via_full = extract(&fp.device, &full, region).unwrap();
            let direct = synth_partial(&fp.device, region, 1234);
            assert_eq!(direct, via_full);
        }
    }

    fn setup() -> (Floorplan, Bitstream) {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        let full = synth_full(&fp.device, 42);
        (fp, full)
    }

    #[test]
    fn extract_covers_exact_frame_set() {
        let (fp, full) = setup();
        let p = extract(&fp.device, &full, &fp.regions[0]).unwrap();
        assert!(p.partial);
        assert_eq!(p.frame_count(), region_frames(&fp.device, &fp.regions[0]).len());
        // Every extracted frame matches the source content.
        for (addr, words) in &p.frames {
            assert_eq!(full.frames.get(addr), Some(words));
        }
    }

    #[test]
    fn relocate_roundtrip_preserves_content() {
        let (fp, full) = setup();
        let p0 = extract(&fp.device, &full, &fp.regions[0]).unwrap();
        let p2 = relocate(&fp.device, &p0, &fp.regions[0], &fp.regions[2]).unwrap();
        assert_eq!(p2.frame_count(), p0.frame_count());
        // Addresses moved by exactly 2 clock regions; content unchanged.
        for (addr, words) in &p0.frames {
            let moved = FrameAddr { clock_region: addr.clock_region + 2, ..*addr };
            assert_eq!(p2.frames.get(&moved), Some(words));
        }
        // And relocating back is the identity.
        let back = relocate(&fp.device, &p2, &fp.regions[2], &fp.regions[0]).unwrap();
        assert_eq!(back, p0);
    }

    #[test]
    fn relocate_rejects_footprint_mismatch() {
        let (fp, full) = setup();
        let p0 = extract(&fp.device, &full, &fp.regions[0]).unwrap();
        let mut bad = fp.regions[1].clone();
        bad.bbox.c1 -= 1; // narrower region
        assert!(matches!(
            relocate(&fp.device, &p0, &fp.regions[0], &bad),
            Err(BitmanError::FootprintMismatch { .. })
        ));
    }

    #[test]
    fn merge_applies_partial() {
        let (fp, full) = setup();
        let other = synth_full(&fp.device, 77);
        let p = extract(&fp.device, &other, &fp.regions[1]).unwrap();
        let mut merged = full.clone();
        let n = merge(&mut merged, &p).unwrap();
        assert_eq!(n, p.frame_count());
        // Region-1 frames now from design 77; everything else untouched.
        for (addr, words) in &merged.frames {
            let in_region = p.frames.contains_key(addr);
            if in_region {
                assert_eq!(words, p.frames.get(addr).unwrap());
            } else {
                assert_eq!(words, full.frames.get(addr).unwrap());
            }
        }
    }

    #[test]
    fn merge_kind_checks() {
        let (fp, full) = setup();
        let p = extract(&fp.device, &full, &fp.regions[0]).unwrap();
        let mut as_partial = p.clone();
        assert!(matches!(merge(&mut as_partial, &p), Err(BitmanError::KindMismatch)));
        let mut f = full.clone();
        let mut fake_full = full.clone();
        fake_full.partial = false;
        assert!(matches!(merge(&mut f, &fake_full), Err(BitmanError::KindMismatch)));
    }

    #[test]
    fn blank_zeroes_region() {
        let (fp, _) = setup();
        let b = blank(&fp.device, &fp.regions[0]);
        assert!(b.frames.values().all(|w| w.iter().all(|&x| x == 0)));
        assert_eq!(b.frame_count(), region_frames(&fp.device, &fp.regions[0]).len());
    }

    #[test]
    fn combined_region_extract() {
        // Combining two adjacent slots (§4.1): a bigger module's region.
        let (fp, full) = setup();
        let combined = PrRegion {
            name: "pr0+1".into(),
            bbox: crate::fabric::Rect {
                c0: fp.regions[0].bbox.c0,
                c1: fp.regions[0].bbox.c1,
                r0: fp.regions[0].bbox.r0,
                r1: fp.regions[1].bbox.r1,
            },
            tunnel_rows: fp.regions[0].tunnel_rows.clone(),
        };
        let p = extract(&fp.device, &full, &combined).unwrap();
        assert_eq!(
            p.frame_count(),
            2 * region_frames(&fp.device, &fp.regions[0]).len()
        );
    }
}
