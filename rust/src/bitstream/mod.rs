//! Synthetic configuration bitstreams + the BitMan analog (§4.1.3).
//!
//! The real FOS extracts a module's configuration frames out of the
//! *full* bitstream Vivado emits for the isolated module compile, then
//! relocates those frames to whichever PR region the scheduler picks at
//! run time (BitMan [31]). We model the UltraScale+ configuration
//! mechanics that make this sound:
//!
//! - configuration is **frame-addressed**: a frame is the column segment
//!   of one clock region (`(clock_region, column, minor)`), the atomic
//!   unit of reconfiguration;
//! - a partial bitstream is a set of frames covering a clock-aligned
//!   bbox;
//! - relocation rewrites the clock-region field of every frame address —
//!   legal iff the source and target footprints are identical, which is
//!   exactly what `fabric::Floorplan::check` guarantees.

mod format;
mod bitman;

pub use bitman::{
    blank, extract, merge, region_frames, relocate, synth_full, synth_partial, BitmanError,
};
pub use format::{Bitstream, Frame, FrameAddr, FormatError, FRAME_WORDS};
