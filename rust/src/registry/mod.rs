//! The JSON registry — the logical hardware abstraction (§4.2).
//!
//! Shells and accelerators are registered as Listing-1/Listing-2 JSON
//! descriptors; upper layers (daemon, client libraries) resolve hardware
//! purely by *logical function name*, never by implementation detail —
//! that's what lets the shell or an accelerator change underneath a
//! running software stack.

use crate::accel::Catalog;
use crate::json::{arr, i, obj, parse, s, to_string_pretty, Value};
use crate::shell::Shell;
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub enum RegistryError {
    Io(std::io::Error),
    Json(String),
    Schema(String),
    NotFound(String),
    Duplicate(String),
}

impl fmt::Display for RegistryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegistryError::Io(e) => write!(f, "registry io: {e}"),
            RegistryError::Json(e) => write!(f, "registry json: {e}"),
            RegistryError::Schema(e) => write!(f, "registry schema: {e}"),
            RegistryError::NotFound(n) => write!(f, "not registered: {n}"),
            RegistryError::Duplicate(n) => write!(f, "already registered: {n}"),
        }
    }
}

impl std::error::Error for RegistryError {}

/// The central JSON-backed registry.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    shells: BTreeMap<String, Value>,
    accels: BTreeMap<String, Value>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register a shell from its Listing-1 descriptor.
    pub fn register_shell(&mut self, descriptor: Value) -> Result<(), RegistryError> {
        let name = descriptor
            .req_str("name")
            .map_err(RegistryError::Schema)?
            .to_string();
        descriptor.req_str("bitfile").map_err(RegistryError::Schema)?;
        descriptor.req_array("regions").map_err(RegistryError::Schema)?;
        if self.shells.insert(name.clone(), descriptor).is_some() {
            return Err(RegistryError::Duplicate(name));
        }
        Ok(())
    }

    /// Register an accelerator from its Listing-2 descriptor.
    pub fn register_accel(&mut self, descriptor: Value) -> Result<(), RegistryError> {
        let name = descriptor
            .req_str("name")
            .map_err(RegistryError::Schema)?
            .to_string();
        descriptor.req_array("bitfiles").map_err(RegistryError::Schema)?;
        descriptor.req_array("registers").map_err(RegistryError::Schema)?;
        if self.accels.insert(name.clone(), descriptor).is_some() {
            return Err(RegistryError::Duplicate(name));
        }
        Ok(())
    }

    /// Replace an existing accelerator descriptor (modular update: new
    /// implementation under the same logical name — §5.4).
    pub fn update_accel(&mut self, descriptor: Value) -> Result<(), RegistryError> {
        let name = descriptor
            .req_str("name")
            .map_err(RegistryError::Schema)?
            .to_string();
        if !self.accels.contains_key(&name) {
            return Err(RegistryError::NotFound(name));
        }
        self.accels.insert(name, descriptor);
        Ok(())
    }

    pub fn shell(&self, name: &str) -> Result<&Value, RegistryError> {
        self.shells
            .get(name)
            .ok_or_else(|| RegistryError::NotFound(name.to_string()))
    }

    pub fn accel(&self, name: &str) -> Result<&Value, RegistryError> {
        self.accels
            .get(name)
            .ok_or_else(|| RegistryError::NotFound(name.to_string()))
    }

    pub fn shell_names(&self) -> Vec<&str> {
        self.shells.keys().map(|k| k.as_str()).collect()
    }

    pub fn accel_names(&self) -> Vec<&str> {
        self.accels.keys().map(|k| k.as_str()).collect()
    }

    /// Build a fully-populated registry: shell descriptor from the
    /// builder + one Listing-2 descriptor per catalogued accelerator.
    pub fn populate(shell: &Shell, catalog: &Catalog) -> Result<Registry, RegistryError> {
        let mut reg = Registry::new();
        reg.register_shell(shell.descriptor())?;
        for a in &catalog.accelerators {
            reg.register_accel(accel_descriptor(shell, a))?;
        }
        Ok(reg)
    }

    /// Serialise to a single registry JSON document.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("shells", arr(self.shells.values().cloned().collect())),
            ("accelerators", arr(self.accels.values().cloned().collect())),
        ])
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), RegistryError> {
        std::fs::write(path, to_string_pretty(&self.to_json())).map_err(RegistryError::Io)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Registry, RegistryError> {
        let text = std::fs::read_to_string(path).map_err(RegistryError::Io)?;
        let v = parse(&text).map_err(|e| RegistryError::Json(e.to_string()))?;
        let mut reg = Registry::new();
        for sh in v.req_array("shells").map_err(RegistryError::Schema)? {
            reg.register_shell(sh.clone())?;
        }
        for a in v.req_array("accelerators").map_err(RegistryError::Schema)? {
            reg.register_accel(a.clone())?;
        }
        Ok(reg)
    }
}

/// Generate the Listing-2 descriptor for an accelerator on a shell —
/// what Vivado-HLS metadata generates automatically in the real flow.
pub fn accel_descriptor(shell: &Shell, a: &crate::accel::Accelerator) -> Value {
    let all_regions: Vec<Value> = shell
        .floorplan
        .regions
        .iter()
        .map(|r| s(r.name.clone()))
        .collect();
    obj(vec![
        ("name", s(a.name.clone())),
        ("lang", s(a.lang.clone())),
        (
            "bitfiles",
            arr(a
                .variants
                .iter()
                .map(|v| {
                    obj(vec![
                        ("name", s(format!("{}.bin", v.name))),
                        ("shell", s(shell.board.name())),
                        // Relocatable: every region is a legal host.
                        ("region", arr(all_regions.clone())),
                        ("regions_needed", i(v.regions as i64)),
                    ])
                })
                .collect()),
        ),
        (
            "registers",
            arr(a
                .registers
                .iter()
                .map(|r| {
                    obj(vec![
                        ("name", s(r.name.clone())),
                        ("offset", s(format!("{:#x}", r.offset))),
                    ])
                })
                .collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shell::ShellBoard;

    fn setup() -> (Shell, Catalog) {
        (
            Shell::build(ShellBoard::Ultra96),
            Catalog::load_default().unwrap(),
        )
    }

    #[test]
    fn populate_and_lookup() {
        let (shell, catalog) = setup();
        let reg = Registry::populate(&shell, &catalog).unwrap();
        assert_eq!(reg.shell_names(), vec!["Ultra96_100MHz_2"]);
        assert_eq!(reg.accel_names().len(), 10);
        let vadd = reg.accel("vadd").unwrap();
        // Listing-2 shape: bitfiles with shell + region list, registers
        // with hex offsets.
        let bf = vadd.req_array("bitfiles").unwrap();
        assert_eq!(bf[0].req_str("shell").unwrap(), "Ultra96");
        assert_eq!(bf[0].req_array("region").unwrap().len(), 3);
        let regs = vadd.req_array("registers").unwrap();
        assert_eq!(regs[0].req_str("name").unwrap(), "control");
        assert_eq!(regs[1].req_str("offset").unwrap(), "0x10");
        assert!(reg.accel("nonexistent").is_err());
    }

    #[test]
    fn duplicate_rejected_update_allowed() {
        let (shell, catalog) = setup();
        let mut reg = Registry::populate(&shell, &catalog).unwrap();
        let vadd = catalog.get("vadd").unwrap();
        let desc = accel_descriptor(&shell, vadd);
        assert!(matches!(
            reg.register_accel(desc.clone()),
            Err(RegistryError::Duplicate(_))
        ));
        // update_accel is the modular-update path (§5.4).
        reg.update_accel(desc).unwrap();
        let mut unknown = accel_descriptor(&shell, vadd);
        if let Value::Object(o) = &mut unknown {
            o.insert("name".into(), s("brand_new"));
        }
        assert!(matches!(
            reg.update_accel(unknown),
            Err(RegistryError::NotFound(_))
        ));
    }

    #[test]
    fn save_load_roundtrip() {
        let (shell, catalog) = setup();
        let reg = Registry::populate(&shell, &catalog).unwrap();
        let dir = std::env::temp_dir().join("fos_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("registry.json");
        reg.save(&path).unwrap();
        let back = Registry::load(&path).unwrap();
        assert_eq!(back.to_json(), reg.to_json());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn schema_validation() {
        let mut reg = Registry::new();
        assert!(matches!(
            reg.register_shell(parse(r#"{"name": "x"}"#).unwrap()),
            Err(RegistryError::Schema(_))
        ));
        assert!(matches!(
            reg.register_accel(parse(r#"{"bitfiles": []}"#).unwrap()),
            Err(RegistryError::Schema(_))
        ));
    }
}
