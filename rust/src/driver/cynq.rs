//! Cynq — the single-tenant acceleration library (§4.3, modes 1 & 2).
//!
//! The Rust face of the paper's C++ "Cynq" (its Python twin "Ponq" is
//! the same API surface): load a shell, allocate contiguous buffers,
//! load accelerators by *logical name*, program their registers through
//! the generic driver, run. Under the hood it drives the whole simulated
//! stack — registry descriptors, BitMan relocation, the FPGA manager's
//! decoupler protocol, and real PJRT compute.

use super::memory::{DataManager, MemError, PhysAddr, TenantId, KERNEL_OWNER};
use super::regs::RegisterFile;
use crate::accel::Catalog;
use crate::bitstream::{relocate, synth_full, synth_partial};
use crate::fabric::{PrRegion, Rect};
use crate::reconfig::{FpgaManager, ReconfigError};
use crate::runtime::Executor;
use crate::shell::{Shell, ShellBoard};
use std::fmt;
use std::time::Duration;

#[derive(Debug)]
pub enum CynqError {
    UnknownAccel(String),
    NoFreeRegions { need: usize },
    /// A region-anchored load targeted an occupied or invalid span.
    RegionOccupied { anchor: usize, span: usize },
    Mem(MemError),
    Reconfig(ReconfigError),
    Exec(String),
    Driver(String),
    BadHandle(usize),
}

impl fmt::Display for CynqError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CynqError::UnknownAccel(n) => write!(f, "no accelerator named {n:?}"),
            CynqError::NoFreeRegions { need } => write!(f, "no {need} adjacent free PR regions"),
            CynqError::RegionOccupied { anchor, span } => {
                write!(f, "regions [{anchor}, {anchor}+{span}) are occupied or invalid")
            }
            CynqError::Mem(e) => write!(f, "{e}"),
            CynqError::Reconfig(e) => write!(f, "{e}"),
            CynqError::Exec(e) => write!(f, "exec: {e}"),
            CynqError::Driver(e) => write!(f, "driver: {e}"),
            CynqError::BadHandle(h) => write!(f, "stale accelerator handle {h}"),
        }
    }
}

impl std::error::Error for CynqError {}

impl From<MemError> for CynqError {
    fn from(e: MemError) -> Self {
        CynqError::Mem(e)
    }
}

impl From<ReconfigError> for CynqError {
    fn from(e: ReconfigError) -> Self {
        CynqError::Reconfig(e)
    }
}

/// Handle to a loaded accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoadedAccel(pub usize);

struct Slot {
    accel: String,
    variant: String,
    /// First region + how many adjacent regions the variant spans.
    anchor: usize,
    span: usize,
    regs: RegisterFile,
    /// Work items completed since load/restore — the progress counter a
    /// checkpoint captures so a resumed batch continues, not restarts.
    tiles_done: u64,
}

/// Captured execution context of a loaded accelerator (preemptive
/// time-multiplexing, §4.4's time domain): the Listing-3 register file
/// plus the progress counter of the interrupted batch.  Produced by
/// [`Cynq::checkpoint_accelerator`], consumed by
/// [`Cynq::restore_accelerator`].
#[derive(Debug, Clone)]
pub struct AccelSnapshot {
    pub accel: String,
    pub variant: String,
    pub tiles_done: u64,
    regs: RegisterFile,
}

/// The library context (one per FPGA).
pub struct Cynq {
    pub shell: Shell,
    pub catalog: Catalog,
    pub manager: FpgaManager,
    pub mem: DataManager,
    pub executor: Executor,
    slots: Vec<Option<Slot>>,
    /// region index -> slot index currently occupying it.
    occupancy: Vec<Option<usize>>,
    /// Modelled hardware time accumulated by `run` calls.
    pub modelled_busy: Duration,
}

impl Cynq {
    /// Open a board: build the shell, load its full bitstream, start the
    /// PJRT executor.
    pub fn open(board: ShellBoard, catalog: Catalog) -> Result<Cynq, CynqError> {
        let shell = Shell::build(board);
        let mut manager =
            FpgaManager::new(shell.floorplan.device.clone(), shell.region_count());
        let full = synth_full(&shell.floorplan.device, 0xF05);
        manager.load_full(full);
        let executor = Executor::new(catalog.clone());
        let n = shell.region_count();
        Ok(Cynq {
            shell,
            catalog,
            manager,
            mem: DataManager::new(64 << 20),
            executor,
            slots: Vec::new(),
            occupancy: vec![None; n],
            modelled_busy: Duration::ZERO,
        })
    }

    pub fn alloc(&mut self, bytes: usize) -> Result<PhysAddr, CynqError> {
        Ok(self.mem.alloc(bytes)?)
    }

    pub fn write_f32(&mut self, addr: PhysAddr, data: &[f32]) -> Result<(), CynqError> {
        Ok(self.mem.write_f32(addr, data)?)
    }

    pub fn read_f32(&self, addr: PhysAddr, n: usize) -> Result<Vec<f32>, CynqError> {
        Ok(self.mem.read_f32(addr, n)?)
    }

    /// Owner-scoped allocation — the daemon's per-tenant arena path.
    pub fn alloc_for(&mut self, owner: TenantId, bytes: usize) -> Result<PhysAddr, CynqError> {
        Ok(self.mem.alloc_for(owner, bytes)?)
    }

    pub fn free_for(&mut self, owner: TenantId, addr: PhysAddr) -> Result<(), CynqError> {
        Ok(self.mem.free_for(owner, addr)?)
    }

    pub fn write_f32_for(
        &mut self,
        owner: TenantId,
        addr: PhysAddr,
        data: &[f32],
    ) -> Result<(), CynqError> {
        Ok(self.mem.write_f32_for(owner, addr, data)?)
    }

    pub fn read_f32_for(
        &self,
        owner: TenantId,
        addr: PhysAddr,
        n: usize,
    ) -> Result<Vec<f32>, CynqError> {
        Ok(self.mem.read_f32_for(owner, addr, n)?)
    }

    /// Find `span` adjacent free regions; returns the anchor index.
    fn find_free(&self, span: usize) -> Option<usize> {
        let n = self.occupancy.len();
        (0..n.saturating_sub(span - 1)).find(|&a| {
            (a..a + span).all(|r| self.occupancy[r].is_none())
                && self.shell.floorplan.combinable(a, span)
        })
    }

    /// Load an accelerator by logical name (mode 2: PR acceleration).
    /// Picks the biggest catalogued variant that fits the free regions —
    /// the paper's Pareto-optimal default (§4.4.3) — unless `variant`
    /// pins one. Returns the handle and the reconfiguration latency.
    pub fn load_accelerator(
        &mut self,
        name: &str,
        variant: Option<&str>,
    ) -> Result<(LoadedAccel, Duration), CynqError> {
        let accel = self
            .catalog
            .get(name)
            .ok_or_else(|| CynqError::UnknownAccel(name.to_string()))?
            .clone();
        let v = match variant {
            Some(vn) => accel
                .variant(vn)
                .ok_or_else(|| CynqError::UnknownAccel(vn.to_string()))?,
            None => {
                // Biggest variant that currently fits.
                let max_free = (1..=self.occupancy.len())
                    .rev()
                    .find(|&k| self.find_free(k).is_some())
                    .unwrap_or(0);
                accel
                    .best_variant_for(max_free)
                    .ok_or(CynqError::NoFreeRegions { need: accel.smallest_variant().regions })?
            }
        }
        .clone();
        let anchor = self
            .find_free(v.regions)
            .ok_or(CynqError::NoFreeRegions { need: v.regions })?;
        self.load_at(&accel, &v, anchor)
    }

    /// Region-anchored load (the scheduler core's API): place `variant`
    /// of `name` exactly at `anchor` — the caller (e.g. the daemon's
    /// dispatcher executing a [`crate::sched::Decision`]) owns the
    /// placement choice. The span must be free and combinable.
    pub fn load_accelerator_at(
        &mut self,
        name: &str,
        variant: &str,
        anchor: usize,
    ) -> Result<(LoadedAccel, Duration), CynqError> {
        let accel = self
            .catalog
            .get(name)
            .ok_or_else(|| CynqError::UnknownAccel(name.to_string()))?
            .clone();
        let v = accel
            .variant(variant)
            .ok_or_else(|| CynqError::UnknownAccel(variant.to_string()))?
            .clone();
        let fits = anchor + v.regions <= self.occupancy.len()
            && (anchor..anchor + v.regions).all(|r| self.occupancy[r].is_none())
            && self.shell.floorplan.combinable(anchor, v.regions);
        if !fits {
            return Err(CynqError::RegionOccupied { anchor, span: v.regions });
        }
        self.load_at(&accel, &v, anchor)
    }

    fn load_at(
        &mut self,
        accel: &crate::accel::Accelerator,
        v: &crate::accel::Variant,
        anchor: usize,
    ) -> Result<(LoadedAccel, Duration), CynqError> {
        // Produce the relocatable partial: compiled-for-pr0 (possibly a
        // combined slot), relocated to the anchor — the BitMan path.
        // synth_partial generates only the module's own frames (§Perf:
        // the original full-device synth + extract dominated the
        // scheduling decision at ~180 us per cold load).
        let device = &self.shell.floorplan.device;
        let src = combined_region(&self.shell, 0, v.regions);
        let dst = combined_region(&self.shell, anchor, v.regions);
        let partial = synth_partial(device, &src, hash(&v.name));
        let partial = relocate(device, &partial, &src, &dst).map_err(ReconfigError::Bitman)?;
        let mut latency = Duration::ZERO;
        for r in anchor..anchor + v.regions {
            self.manager.set_decoupler(r, true)?;
        }
        // One PCAP write covers the combined span.
        latency += {
            // load_partial checks the anchor's decoupler; mark all spans.
            self.manager.set_decoupler(anchor, true)?;
            self.manager.load_partial(anchor, &partial)?
        };
        let slot = Slot {
            accel: accel.name.clone(),
            variant: v.name.clone(),
            anchor,
            span: v.regions,
            regs: RegisterFile::new(&accel.registers),
            tiles_done: 0,
        };
        let idx = self.slots.len();
        self.slots.push(Some(slot));
        for r in anchor..anchor + v.regions {
            self.occupancy[r] = Some(idx);
        }
        Ok((LoadedAccel(idx), latency))
    }

    /// Unload (blank) an accelerator, freeing its regions.
    pub fn unload(&mut self, h: LoadedAccel) -> Result<(), CynqError> {
        let slot = self
            .slots
            .get_mut(h.0)
            .and_then(Option::take)
            .ok_or(CynqError::BadHandle(h.0))?;
        for r in slot.anchor..slot.anchor + slot.span {
            self.occupancy[r] = None;
        }
        Ok(())
    }

    /// Program an operand register by name (generic driver, §4.3).
    pub fn write_reg(
        &mut self,
        h: LoadedAccel,
        reg: &str,
        value: PhysAddr,
    ) -> Result<(), CynqError> {
        let slot = self
            .slots
            .get_mut(h.0)
            .and_then(Option::as_mut)
            .ok_or(CynqError::BadHandle(h.0))?;
        slot.regs.write_by_name(reg, value.0).map_err(CynqError::Driver)
    }

    /// ap_start + run to completion (blocking). The "hardware" reads its
    /// operands from the data manager at the programmed addresses,
    /// executes the variant's HLO on PJRT, and DMA-writes the outputs
    /// back. Returns the *modelled* FPGA latency for the work item.
    ///
    /// Runs in the kernel ownership domain — the in-process library
    /// path. The daemon dispatches through [`Cynq::run_as`] so the DMA
    /// engine itself re-verifies that every operand buffer belongs to
    /// the job's tenant (defense in depth behind the handle table).
    pub fn run(&mut self, h: LoadedAccel) -> Result<Duration, CynqError> {
        self.run_as(h, KERNEL_OWNER)
    }

    /// [`Cynq::run`] on behalf of one tenant: every operand DMA is
    /// bounds- *and* ownership-checked against `owner`'s arena, so a
    /// mis-programmed (or maliciously forged) operand register can
    /// never move another tenant's data through the fabric.
    pub fn run_as(&mut self, h: LoadedAccel, owner: TenantId) -> Result<Duration, CynqError> {
        let (accel_name, variant_name, operands) = {
            let slot = self
                .slots
                .get_mut(h.0)
                .and_then(Option::as_mut)
                .ok_or(CynqError::BadHandle(h.0))?;
            slot.regs.write(0, super::regs::ControlBits::AP_START as u64);
            (slot.accel.clone(), slot.variant.clone(), slot.regs.operands())
        };
        let accel = self.catalog.get(&accel_name).unwrap().clone();
        let variant = accel.variant(&variant_name).unwrap().clone();
        if operands.len() != accel.inputs.len() + accel.outputs.len() {
            return Err(CynqError::Driver(format!(
                "{}: {} operand registers for {} inputs + {} outputs",
                accel.name,
                operands.len(),
                accel.inputs.len(),
                accel.outputs.len()
            )));
        }
        // DMA in: gather inputs (ownership-checked per operand).
        let mut inputs = Vec::new();
        for (spec, (_, addr)) in accel.inputs.iter().zip(&operands) {
            inputs.push(self.mem.read_f32_for(owner, PhysAddr(*addr), spec.elements())?);
        }
        // Execute on PJRT.
        let out = self
            .executor
            .execute(&variant.name, inputs)
            .map_err(CynqError::Exec)?;
        // DMA out: scatter outputs.
        for ((spec, buf), (_, addr)) in accel
            .outputs
            .iter()
            .zip(&out.outputs)
            .zip(operands[accel.inputs.len()..].iter())
        {
            let _ = spec;
            self.mem.write_f32_for(owner, PhysAddr(*addr), buf)?;
        }
        if let Some(slot) = self.slots.get_mut(h.0).and_then(Option::as_mut) {
            slot.regs.complete();
            slot.tiles_done += 1;
        }
        // Modelled FPGA latency: DMA (memsim) + compute (cycle model).
        let mem = crate::memsim::DdrModel::new(crate::memsim::config_for(self.shell.board));
        let busy_regions = self.occupancy.iter().flatten().count().saturating_sub(1);
        let dma_ns = mem.transfer_ns(accel.bytes_in, busy_regions)
            + mem.transfer_ns(accel.bytes_out, busy_regions);
        let modelled = Duration::from_nanos((variant.compute_ns() + dma_ns) as u64);
        self.modelled_busy += modelled;
        Ok(modelled)
    }

    /// Checkpoint a loaded accelerator: snapshot its register file and
    /// progress counter so the batch can be resumed later — possibly
    /// after the module was replaced and reloaded (the scheduler's
    /// `Preempt`/`Resume` decisions drive this on the daemon path).
    pub fn checkpoint_accelerator(&self, h: LoadedAccel) -> Result<AccelSnapshot, CynqError> {
        let slot = self
            .slots
            .get(h.0)
            .and_then(Option::as_ref)
            .ok_or(CynqError::BadHandle(h.0))?;
        Ok(AccelSnapshot {
            accel: slot.accel.clone(),
            variant: slot.variant.clone(),
            tiles_done: slot.tiles_done,
            regs: slot.regs.clone(),
        })
    }

    /// Restore a checkpoint onto a loaded accelerator.  The target must
    /// run the snapshot's exact accelerator/variant (the register file
    /// layout and progress semantics are variant-specific); on mismatch
    /// the slot is left untouched — rollback-on-failure mirroring
    /// [`Cynq::load_accelerator_at`]'s no-partial-effect contract.
    pub fn restore_accelerator(
        &mut self,
        h: LoadedAccel,
        snap: &AccelSnapshot,
    ) -> Result<(), CynqError> {
        let slot = self
            .slots
            .get_mut(h.0)
            .and_then(Option::as_mut)
            .ok_or(CynqError::BadHandle(h.0))?;
        if slot.accel != snap.accel || slot.variant != snap.variant {
            return Err(CynqError::Driver(format!(
                "snapshot of {}/{} cannot restore onto {}/{}",
                snap.accel, snap.variant, slot.accel, slot.variant
            )));
        }
        slot.regs = snap.regs.clone();
        slot.tiles_done = snap.tiles_done;
        Ok(())
    }

    /// Work items completed on a live handle since load/restore.
    pub fn progress_of(&self, h: LoadedAccel) -> Option<u64> {
        self.slots.get(h.0).and_then(Option::as_ref).map(|s| s.tiles_done)
    }

    /// Which variant a handle currently runs (for tests/inspection).
    pub fn variant_of(&self, h: LoadedAccel) -> Option<&str> {
        self.slots
            .get(h.0)
            .and_then(Option::as_ref)
            .map(|s| s.variant.as_str())
    }

    /// `(anchor, span)` of a live handle.
    pub fn anchor_of(&self, h: LoadedAccel) -> Option<(usize, usize)> {
        self.slots
            .get(h.0)
            .and_then(Option::as_ref)
            .map(|s| (s.anchor, s.span))
    }

    /// Handle of the module whose span covers `region`, if any.
    pub fn occupant(&self, region: usize) -> Option<LoadedAccel> {
        self.occupancy.get(region).copied().flatten().map(LoadedAccel)
    }

    pub fn free_regions(&self) -> usize {
        self.occupancy.iter().filter(|o| o.is_none()).count()
    }
}

/// The (possibly combined) PR region starting at `anchor` spanning
/// `span` slots.
pub fn combined_region(shell: &Shell, anchor: usize, span: usize) -> PrRegion {
    let rs = &shell.floorplan.regions;
    PrRegion {
        name: if span == 1 {
            rs[anchor].name.clone()
        } else {
            format!("{}+{}", rs[anchor].name, span - 1)
        },
        bbox: Rect {
            c0: rs[anchor].bbox.c0,
            c1: rs[anchor].bbox.c1,
            r0: rs[anchor].bbox.r0,
            r1: rs[anchor + span - 1].bbox.r1,
        },
        tunnel_rows: rs[anchor].tunnel_rows.clone(),
    }
}

fn hash(s: &str) -> u64 {
    s.bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;
    use std::sync::Mutex;

    // Serialise Cynq tests: each opens a PJRT client thread; cheap, but
    // keep memory bounded.
    static LOCK: Mutex<()> = Mutex::new(());

    fn open() -> Cynq {
        Cynq::open(ShellBoard::Ultra96, Catalog::load_default().unwrap()).unwrap()
    }

    #[test]
    fn quickstart_vadd_end_to_end() {
        let _g = LOCK.lock().unwrap();
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let mut fpga = open();
        let mut rng = Rng::new(5);
        let a: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
        let pa = fpga.alloc(4 * 4096).unwrap();
        let pb = fpga.alloc(4 * 4096).unwrap();
        let pc = fpga.alloc(4 * 4096).unwrap();
        fpga.write_f32(pa, &a).unwrap();
        fpga.write_f32(pb, &b).unwrap();
        let (h, reconfig) = fpga.load_accelerator("vadd", Some("vadd_v1")).unwrap();
        assert!(reconfig > Duration::ZERO);
        fpga.write_reg(h, "a_op", pa).unwrap();
        fpga.write_reg(h, "b_op", pb).unwrap();
        fpga.write_reg(h, "c_out", pc).unwrap();
        let modelled = fpga.run(h).unwrap();
        assert!(modelled > Duration::ZERO);
        let c = fpga.read_f32(pc, 4096).unwrap();
        for k in 0..4096 {
            assert!((c[k] - (a[k] + b[k])).abs() < 1e-5);
        }
    }

    #[test]
    fn elastic_variant_selection_uses_biggest() {
        let _g = LOCK.lock().unwrap();
        let mut fpga = open();
        // 3 free regions: the 2-region dct_v2 should be chosen.
        let (h, _) = fpga.load_accelerator("dct", None).unwrap();
        assert_eq!(fpga.variant_of(h), Some("dct_v2"));
        assert_eq!(fpga.free_regions(), 1);
        // Next load only has 1 region left -> v1.
        let (h2, _) = fpga.load_accelerator("dct", None).unwrap();
        assert_eq!(fpga.variant_of(h2), Some("dct_v1"));
        assert_eq!(fpga.free_regions(), 0);
        // Third load fails.
        assert!(matches!(
            fpga.load_accelerator("dct", None),
            Err(CynqError::NoFreeRegions { .. })
        ));
        // Unload the big one: two adjacent slots free again.
        fpga.unload(h).unwrap();
        assert_eq!(fpga.free_regions(), 2);
        let (h3, _) = fpga.load_accelerator("vadd", None).unwrap();
        assert_eq!(fpga.variant_of(h3), Some("vadd_v2"));
    }

    #[test]
    fn region_anchored_load() {
        let _g = LOCK.lock().unwrap();
        let mut fpga = open();
        // Pin vadd_v1 to region 1; region 0 and 2 stay free.
        let (h, _) = fpga.load_accelerator_at("vadd", "vadd_v1", 1).unwrap();
        assert_eq!(fpga.anchor_of(h), Some((1, 1)));
        assert_eq!(fpga.occupant(1), Some(h));
        assert_eq!(fpga.occupant(0), None);
        // The span is taken now.
        assert!(matches!(
            fpga.load_accelerator_at("vadd", "vadd_v1", 1),
            Err(CynqError::RegionOccupied { .. })
        ));
        // A 2-region variant cannot anchor where its tail is occupied.
        assert!(matches!(
            fpga.load_accelerator_at("vadd", "vadd_v2", 0),
            Err(CynqError::RegionOccupied { .. })
        ));
        // ...but fits after the blocker is unloaded.
        fpga.unload(h).unwrap();
        let (h2, _) = fpga.load_accelerator_at("vadd", "vadd_v2", 0).unwrap();
        assert_eq!(fpga.anchor_of(h2), Some((0, 2)));
        // Out-of-fabric anchors rejected.
        assert!(fpga.load_accelerator_at("vadd", "vadd_v1", 9).is_err());
    }

    #[test]
    fn unknown_names_rejected() {
        let _g = LOCK.lock().unwrap();
        let mut fpga = open();
        assert!(matches!(
            fpga.load_accelerator("warp_drive", None),
            Err(CynqError::UnknownAccel(_))
        ));
        assert!(matches!(
            fpga.load_accelerator("vadd", Some("vadd_v9")),
            Err(CynqError::UnknownAccel(_))
        ));
    }

    #[test]
    fn stale_handle_rejected() {
        let _g = LOCK.lock().unwrap();
        let mut fpga = open();
        let (h, _) = fpga.load_accelerator("vadd", Some("vadd_v1")).unwrap();
        fpga.unload(h).unwrap();
        assert!(matches!(fpga.run(h), Err(CynqError::BadHandle(_))));
        assert!(matches!(fpga.unload(h), Err(CynqError::BadHandle(_))));
    }

    #[test]
    fn checkpoint_restore_roundtrip() {
        let _g = LOCK.lock().unwrap();
        let mut fpga = open();
        let (h, _) = fpga.load_accelerator("vadd", Some("vadd_v1")).unwrap();
        let pa = fpga.alloc(4 * 4096).unwrap();
        fpga.write_reg(h, "a_op", pa).unwrap();
        assert_eq!(fpga.progress_of(h), Some(0));
        let snap = fpga.checkpoint_accelerator(h).unwrap();
        assert_eq!((snap.accel.as_str(), snap.variant.as_str()), ("vadd", "vadd_v1"));
        assert_eq!(snap.tiles_done, 0);

        // Replace the module entirely, then bring vadd back and restore:
        // the programmed register survives the checkpoint, not the slot.
        fpga.unload(h).unwrap();
        let (h2, _) = fpga.load_accelerator("vadd", Some("vadd_v1")).unwrap();
        fpga.restore_accelerator(h2, &snap).unwrap();
        // a_op was restored from the snapshot without reprogramming.
        // (run() would still fail on the unprogrammed b_op/c_out, which
        // is exactly the state the checkpoint captured.)
        assert_eq!(fpga.progress_of(h2), Some(0));

        // Mismatched restore is rejected and leaves the slot untouched.
        let (h3, _) = fpga.load_accelerator("dct", None).unwrap();
        assert!(matches!(
            fpga.restore_accelerator(h3, &snap),
            Err(CynqError::Driver(_))
        ));
        assert_eq!(fpga.progress_of(h3), Some(0));
        // Stale handles rejected for both operations.
        fpga.unload(h2).unwrap();
        assert!(matches!(fpga.checkpoint_accelerator(h2), Err(CynqError::BadHandle(_))));
        assert!(matches!(
            fpga.restore_accelerator(h2, &snap),
            Err(CynqError::BadHandle(_))
        ));
    }

    #[test]
    fn progress_counter_tracks_completed_tiles() {
        let _g = LOCK.lock().unwrap();
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let mut fpga = open();
        let pa = fpga.alloc(4 * 4096).unwrap();
        let pb = fpga.alloc(4 * 4096).unwrap();
        let pc = fpga.alloc(4 * 4096).unwrap();
        fpga.write_f32(pa, &vec![1.0; 4096]).unwrap();
        fpga.write_f32(pb, &vec![2.0; 4096]).unwrap();
        let (h, _) = fpga.load_accelerator("vadd", Some("vadd_v1")).unwrap();
        fpga.write_reg(h, "a_op", pa).unwrap();
        fpga.write_reg(h, "b_op", pb).unwrap();
        fpga.write_reg(h, "c_out", pc).unwrap();
        fpga.run(h).unwrap();
        fpga.run(h).unwrap();
        assert_eq!(fpga.progress_of(h), Some(2));
        let snap = fpga.checkpoint_accelerator(h).unwrap();
        assert_eq!(snap.tiles_done, 2);
        fpga.run(h).unwrap();
        assert_eq!(fpga.progress_of(h), Some(3));
        // Restore rewinds the progress counter to the checkpoint.
        fpga.restore_accelerator(h, &snap).unwrap();
        assert_eq!(fpga.progress_of(h), Some(2));
    }

    #[test]
    fn restore_mismatch_rolls_back_slot_state() {
        // The restore rollback path in isolation: a snapshot of one
        // variant must refuse to land on any other accelerator OR
        // variant, leaving the target slot byte-for-byte untouched —
        // the no-partial-effect contract cross-board migration relies
        // on (a migrated snapshot only restores onto a fresh load of
        // the exact same variant).
        let _g = LOCK.lock().unwrap();
        let mut fpga = open();
        let (h1, _) = fpga.load_accelerator("vadd", Some("vadd_v1")).unwrap();
        let pa = fpga.alloc(4 * 4096).unwrap();
        fpga.write_reg(h1, "a_op", pa).unwrap();
        let snap = fpga.checkpoint_accelerator(h1).unwrap();
        fpga.unload(h1).unwrap();

        // Same accelerator, different variant: refused.
        let (h2, _) = fpga.load_accelerator_at("vadd", "vadd_v2", 0).unwrap();
        let pb = fpga.alloc(4 * 4096).unwrap();
        fpga.write_reg(h2, "b_op", pb).unwrap();
        let before = fpga.checkpoint_accelerator(h2).unwrap();
        assert!(matches!(
            fpga.restore_accelerator(h2, &snap),
            Err(CynqError::Driver(_))
        ));
        // Slot state survived the refused restore: variant, progress
        // and the register file are exactly what they were.
        assert_eq!(fpga.variant_of(h2), Some("vadd_v2"));
        assert_eq!(fpga.progress_of(h2), Some(before.tiles_done));
        let after = fpga.checkpoint_accelerator(h2).unwrap();
        assert_eq!(after.regs, before.regs, "register file must be untouched");
        fpga.unload(h2).unwrap();

        // Different accelerator entirely: also refused, also untouched.
        let (h3, _) = fpga.load_accelerator("sobel", Some("sobel_v1")).unwrap();
        assert!(fpga.restore_accelerator(h3, &snap).is_err());
        assert_eq!(fpga.variant_of(h3), Some("sobel_v1"));
        assert_eq!(fpga.progress_of(h3), Some(0));

        // The exact variant restores cleanly — and carries the
        // programmed operand register across the reload.
        fpga.unload(h3).unwrap();
        let (h4, _) = fpga.load_accelerator("vadd", Some("vadd_v1")).unwrap();
        fpga.restore_accelerator(h4, &snap).unwrap();
        let restored = fpga.checkpoint_accelerator(h4).unwrap();
        assert_eq!(restored.regs, snap.regs, "restore must reinstate the register file");
    }

    #[test]
    fn failed_load_has_no_partial_effect() {
        // The load-failure rollback path in isolation: a load refused
        // for capacity (the "third load") or for an occupied/invalid
        // anchor must leave occupancy, live handles and register state
        // exactly as they were — the daemon maps these CynqErrors into
        // the scheduler's retry path, which assumes nothing changed.
        let _g = LOCK.lock().unwrap();
        let mut fpga = open(); // Ultra96: 3 PR regions
        let (h1, _) = fpga.load_accelerator("dct", None).unwrap(); // dct_v2, 2 regions
        let (h2, _) = fpga.load_accelerator("dct", None).unwrap(); // dct_v1, 1 region
        assert_eq!(fpga.free_regions(), 0);
        let pa = fpga.alloc(4096).unwrap();
        fpga.write_reg(h2, "in_img", pa).unwrap();
        let before = fpga.checkpoint_accelerator(h2).unwrap();

        // Third load fails for capacity…
        assert!(matches!(
            fpga.load_accelerator("dct", None),
            Err(CynqError::NoFreeRegions { .. })
        ));
        // …an anchored load fails for occupancy…
        assert!(matches!(
            fpga.load_accelerator_at("vadd", "vadd_v1", 0),
            Err(CynqError::RegionOccupied { .. })
        ));
        // …an out-of-fabric anchor fails…
        assert!(fpga.load_accelerator_at("vadd", "vadd_v1", 17).is_err());
        // …and none of it perturbed anything: occupancy, both live
        // handles, and the programmed register file are unchanged.
        assert_eq!(fpga.free_regions(), 0);
        assert_eq!(fpga.variant_of(h1), Some("dct_v2"));
        assert_eq!(fpga.variant_of(h2), Some("dct_v1"));
        assert_eq!(fpga.occupant(0), Some(h1));
        assert_eq!(fpga.occupant(2), Some(h2));
        let after = fpga.checkpoint_accelerator(h2).unwrap();
        assert_eq!(after.regs, before.regs);
        // Recovery after freeing capacity works first try — the failed
        // attempts left no poisoned state behind.
        fpga.unload(h1).unwrap();
        let (h3, _) = fpga.load_accelerator_at("vadd", "vadd_v1", 0).unwrap();
        assert_eq!(fpga.anchor_of(h3), Some((0, 1)));
    }

    #[test]
    fn missing_register_programming_caught() {
        let _g = LOCK.lock().unwrap();
        let mut fpga = open();
        let (h, _) = fpga.load_accelerator("vadd", Some("vadd_v1")).unwrap();
        let pa = fpga.alloc(4 * 4096).unwrap();
        fpga.write_reg(h, "a_op", pa).unwrap();
        // b_op / c_out default to 0 -> DMA from unmapped address errors.
        assert!(fpga.run(h).is_err());
    }
}
