//! Contiguous physical memory allocation — the CMA/udmabuf analog.
//!
//! Accelerators see *physical* addresses: software allocates a buffer,
//! gets its phys addr, and programs that into the operand registers
//! (Listings 4–5 pass `a_op_phy_addr` etc.). The data manager owns a
//! DDR-backed arena starting at the PL-visible base and hands out
//! aligned, contiguous ranges with a first-fit free list.

use std::collections::BTreeMap;
use std::fmt;

/// PL-visible DDR window base (matches the Zynq address map's low-DDR
/// aperture the HP ports target).
pub const DDR_BASE: u64 = 0x4000_0000;

/// Allocation alignment: AXI bursts must not cross 4 KiB boundaries.
pub const ALIGN: u64 = 4096;

/// A physical address inside the accelerator-visible DDR window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PhysAddr(pub u64);

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MemError {
    OutOfMemory { requested: usize, largest_free: usize },
    BadFree(PhysAddr),
    OutOfRange { addr: PhysAddr, len: usize },
}

impl fmt::Display for MemError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MemError::OutOfMemory { requested, largest_free } => {
                write!(f, "out of contiguous memory: requested {requested}, largest free {largest_free}")
            }
            MemError::BadFree(a) => write!(f, "free of unallocated address {a:?}"),
            MemError::OutOfRange { addr, len } => {
                write!(f, "access [{addr:?} +{len}] outside allocation")
            }
        }
    }
}

impl std::error::Error for MemError {}

/// The arena: backing store + allocation bookkeeping.
pub struct DataManager {
    mem: Vec<u8>,
    /// offset -> length of live allocations.
    allocs: BTreeMap<u64, usize>,
}

impl DataManager {
    /// An arena of `size` bytes (the PL-visible CMA pool).
    pub fn new(size: usize) -> DataManager {
        DataManager { mem: vec![0; size], allocs: BTreeMap::new() }
    }

    pub fn capacity(&self) -> usize {
        self.mem.len()
    }

    pub fn allocated_bytes(&self) -> usize {
        self.allocs.values().sum()
    }

    /// First-fit aligned allocation.
    pub fn alloc(&mut self, size: usize) -> Result<PhysAddr, MemError> {
        let size_al = size.max(1);
        let mut cursor = 0u64;
        let mut largest_free = 0usize;
        let mut fit: Option<u64> = None;
        for (&off, &len) in &self.allocs {
            let gap = (off.saturating_sub(cursor)) as usize;
            largest_free = largest_free.max(gap);
            if fit.is_none() && gap >= size_al {
                fit = Some(cursor);
            }
            cursor = align_up(off + len as u64);
        }
        let tail = self.mem.len().saturating_sub(cursor as usize);
        largest_free = largest_free.max(tail);
        if fit.is_none() && tail >= size_al {
            fit = Some(cursor);
        }
        match fit {
            Some(off) => {
                self.allocs.insert(off, size_al);
                Ok(PhysAddr(DDR_BASE + off))
            }
            None => Err(MemError::OutOfMemory { requested: size_al, largest_free }),
        }
    }

    pub fn free(&mut self, addr: PhysAddr) -> Result<(), MemError> {
        let off = addr.0.checked_sub(DDR_BASE).ok_or(MemError::BadFree(addr))?;
        self.allocs.remove(&off).ok_or(MemError::BadFree(addr))?;
        Ok(())
    }

    fn check(&self, addr: PhysAddr, len: usize) -> Result<usize, MemError> {
        let off = addr
            .0
            .checked_sub(DDR_BASE)
            .ok_or(MemError::OutOfRange { addr, len })? as usize;
        // The access must lie inside one live allocation (the DMA cannot
        // scribble outside its buffer — a real CMA property worth keeping).
        let ok = self
            .allocs
            .range(..=off as u64)
            .next_back()
            .map(|(&a, &l)| off >= a as usize && off + len <= a as usize + l)
            .unwrap_or(false);
        if !ok {
            return Err(MemError::OutOfRange { addr, len });
        }
        Ok(off)
    }

    /// CPU/DMA write of f32 data.
    pub fn write_f32(&mut self, addr: PhysAddr, data: &[f32]) -> Result<(), MemError> {
        let off = self.check(addr, data.len() * 4)?;
        for (k, v) in data.iter().enumerate() {
            self.mem[off + 4 * k..off + 4 * k + 4].copy_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    /// CPU/DMA read of f32 data.
    pub fn read_f32(&self, addr: PhysAddr, count: usize) -> Result<Vec<f32>, MemError> {
        let off = self.check(addr, count * 4)?;
        Ok((0..count)
            .map(|k| {
                f32::from_le_bytes(self.mem[off + 4 * k..off + 4 * k + 4].try_into().unwrap())
            })
            .collect())
    }

    pub fn write_bytes(&mut self, addr: PhysAddr, data: &[u8]) -> Result<(), MemError> {
        let off = self.check(addr, data.len())?;
        self.mem[off..off + data.len()].copy_from_slice(data);
        Ok(())
    }

    pub fn read_bytes(&self, addr: PhysAddr, len: usize) -> Result<Vec<u8>, MemError> {
        let off = self.check(addr, len)?;
        Ok(self.mem[off..off + len].to_vec())
    }
}

fn align_up(x: u64) -> u64 {
    (x + ALIGN - 1) & !(ALIGN - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_write_read_roundtrip() {
        let mut dm = DataManager::new(1 << 20);
        let a = dm.alloc(4096).unwrap();
        assert_eq!(a.0 % ALIGN, 0);
        assert!(a.0 >= DDR_BASE);
        let data: Vec<f32> = (0..1024).map(|i| i as f32).collect();
        dm.write_f32(a, &data).unwrap();
        assert_eq!(dm.read_f32(a, 1024).unwrap(), data);
    }

    #[test]
    fn allocations_disjoint_and_aligned() {
        let mut dm = DataManager::new(1 << 20);
        let addrs: Vec<PhysAddr> = (0..10).map(|_| dm.alloc(1000).unwrap()).collect();
        for w in addrs.windows(2) {
            assert!(w[1].0 >= w[0].0 + 1000);
            assert_eq!(w[1].0 % ALIGN, 0);
        }
    }

    #[test]
    fn free_then_reuse() {
        let mut dm = DataManager::new(16 * 4096);
        let a = dm.alloc(4096).unwrap();
        let _b = dm.alloc(4096).unwrap();
        dm.free(a).unwrap();
        let c = dm.alloc(4096).unwrap();
        assert_eq!(c, a, "first-fit should reuse the freed hole");
        assert!(matches!(dm.free(a), Ok(())));
        assert!(matches!(dm.free(a), Err(MemError::BadFree(_))));
    }

    #[test]
    fn oom_reported_with_sizes() {
        let mut dm = DataManager::new(8192);
        let _a = dm.alloc(4096).unwrap();
        let err = dm.alloc(8192).unwrap_err();
        assert!(matches!(err, MemError::OutOfMemory { requested: 8192, .. }));
    }

    #[test]
    fn out_of_bounds_access_rejected() {
        let mut dm = DataManager::new(1 << 16);
        let a = dm.alloc(64).unwrap();
        assert!(dm.write_f32(a, &vec![0.0; 17]).is_err()); // 68 bytes > 64
        assert!(dm.read_f32(PhysAddr(DDR_BASE + 60_000), 4).is_err());
        assert!(dm.read_f32(PhysAddr(0), 1).is_err()); // below DDR base
        // Interior access within an allocation is fine.
        let mid = PhysAddr(a.0 + 16);
        dm.write_f32(mid, &[1.0, 2.0]).unwrap();
        assert_eq!(dm.read_f32(mid, 2).unwrap(), vec![1.0, 2.0]);
    }
}
