//! Contiguous physical memory allocation — the CMA/udmabuf analog —
//! partitioned into per-tenant ownership domains.
//!
//! Accelerators see *physical* addresses: software allocates a buffer,
//! gets its phys addr, and programs that into the operand registers
//! (Listings 4–5 pass `a_op_phy_addr` etc.). The data manager owns a
//! DDR-backed arena starting at the PL-visible base and hands out
//! aligned, contiguous ranges with a first-fit free list.
//!
//! Every allocation carries an owning [`TenantId`]; all access paths
//! (read/write/free) verify both bounds *and* ownership, so a dispatch
//! acting for one tenant can never touch another tenant's buffers even
//! if it guesses a valid physical address. [`TenantId`] 0 is the
//! [`KERNEL_OWNER`] — the in-process/driver-local domain used when no
//! multi-tenant boundary exists (unit tests, single-user embedding).
//! It is *not* a superuser: kernel-owned buffers are simply one more
//! disjoint domain. Retiring a tenant reclaims its whole arena in one
//! call ([`DataManager::reclaim_tenant`]).

use std::collections::BTreeMap;
use std::fmt;

/// PL-visible DDR window base (matches the Zynq address map's low-DDR
/// aperture the HP ports target).
pub const DDR_BASE: u64 = 0x4000_0000;

/// Allocation alignment: AXI bursts must not cross 4 KiB boundaries.
pub const ALIGN: u64 = 4096;

/// Owner of an allocation. The daemon maps its admission tenant id `t`
/// to arena owner `t + 1` so tenant 0 never collides with the kernel
/// domain.
pub type TenantId = u32;

/// The in-process ownership domain (driver-local use, unit tests).
pub const KERNEL_OWNER: TenantId = 0;

/// A physical address inside the accelerator-visible DDR window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PhysAddr(pub u64);

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MemError {
    OutOfMemory { requested: usize, largest_free: usize },
    BadFree(PhysAddr),
    OutOfRange { addr: PhysAddr, len: usize },
    /// Bounds were fine but the buffer belongs to a different tenant.
    /// `owner` is the domain that attempted the access, never the
    /// domain that holds the buffer (the denied party learns nothing
    /// about who owns the range it probed).
    Foreign { addr: PhysAddr, owner: TenantId },
}

impl fmt::Display for MemError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MemError::OutOfMemory { requested, largest_free } => {
                write!(f, "out of contiguous memory: requested {requested}, largest free {largest_free}")
            }
            MemError::BadFree(a) => write!(f, "free of unallocated address {a:?}"),
            MemError::OutOfRange { addr, len } => {
                write!(f, "access [{addr:?} +{len}] outside allocation")
            }
            MemError::Foreign { addr, owner } => {
                write!(f, "access denied: {addr:?} is not owned by tenant {owner}")
            }
        }
    }
}

impl std::error::Error for MemError {}

#[derive(Debug, Clone, Copy)]
struct Allocation {
    len: usize,
    owner: TenantId,
}

/// The arena: backing store + allocation bookkeeping.
pub struct DataManager {
    mem: Vec<u8>,
    /// offset -> live allocation record.
    allocs: BTreeMap<u64, Allocation>,
}

impl DataManager {
    /// An arena of `size` bytes (the PL-visible CMA pool).
    pub fn new(size: usize) -> DataManager {
        DataManager { mem: vec![0; size], allocs: BTreeMap::new() }
    }

    pub fn capacity(&self) -> usize {
        self.mem.len()
    }

    pub fn allocated_bytes(&self) -> usize {
        self.allocs.values().map(|a| a.len).sum()
    }

    /// Live bytes held by one tenant — the leak-check counter.
    pub fn tenant_bytes(&self, owner: TenantId) -> usize {
        self.allocs.values().filter(|a| a.owner == owner).map(|a| a.len).sum()
    }

    /// Owner of the allocation containing `addr`, if any.
    pub fn owner_of(&self, addr: PhysAddr) -> Option<TenantId> {
        let off = addr.0.checked_sub(DDR_BASE)? as usize;
        self.allocs
            .range(..=off as u64)
            .next_back()
            .filter(|(&a, al)| off >= a as usize && off < a as usize + al.len)
            .map(|(_, al)| al.owner)
    }

    /// First-fit aligned allocation in the kernel domain.
    pub fn alloc(&mut self, size: usize) -> Result<PhysAddr, MemError> {
        self.alloc_for(KERNEL_OWNER, size)
    }

    /// First-fit aligned allocation owned by `owner`.
    pub fn alloc_for(&mut self, owner: TenantId, size: usize) -> Result<PhysAddr, MemError> {
        let size_al = size.max(1);
        let mut cursor = 0u64;
        let mut largest_free = 0usize;
        let mut fit: Option<u64> = None;
        for (&off, al) in &self.allocs {
            let gap = (off.saturating_sub(cursor)) as usize;
            largest_free = largest_free.max(gap);
            if fit.is_none() && gap >= size_al {
                fit = Some(cursor);
            }
            cursor = align_up(off + al.len as u64);
        }
        let tail = self.mem.len().saturating_sub(cursor as usize);
        largest_free = largest_free.max(tail);
        if fit.is_none() && tail >= size_al {
            fit = Some(cursor);
        }
        match fit {
            Some(off) => {
                self.allocs.insert(off, Allocation { len: size_al, owner });
                Ok(PhysAddr(DDR_BASE + off))
            }
            None => Err(MemError::OutOfMemory { requested: size_al, largest_free }),
        }
    }

    /// Free a kernel-domain allocation.
    pub fn free(&mut self, addr: PhysAddr) -> Result<(), MemError> {
        self.free_for(KERNEL_OWNER, addr)
    }

    /// Free an allocation owned by `owner`. Freeing another tenant's
    /// buffer is `Foreign`, not `BadFree` — the buffer stays live.
    pub fn free_for(&mut self, owner: TenantId, addr: PhysAddr) -> Result<(), MemError> {
        let off = addr.0.checked_sub(DDR_BASE).ok_or(MemError::BadFree(addr))?;
        match self.allocs.get(&off) {
            None => Err(MemError::BadFree(addr)),
            Some(al) if al.owner != owner => Err(MemError::Foreign { addr, owner }),
            Some(_) => {
                self.allocs.remove(&off);
                Ok(())
            }
        }
    }

    /// Tear down a retired tenant's whole arena; returns the bytes
    /// reclaimed. Idempotent — a second call reclaims nothing.
    pub fn reclaim_tenant(&mut self, owner: TenantId) -> usize {
        let mut reclaimed = 0usize;
        self.allocs.retain(|_, al| {
            if al.owner == owner {
                reclaimed += al.len;
                false
            } else {
                true
            }
        });
        reclaimed
    }

    fn check(&self, owner: TenantId, addr: PhysAddr, len: usize) -> Result<usize, MemError> {
        let off = addr
            .0
            .checked_sub(DDR_BASE)
            .ok_or(MemError::OutOfRange { addr, len })? as usize;
        // The access must lie inside one live allocation (the DMA cannot
        // scribble outside its buffer — a real CMA property worth keeping)
        // and that allocation must belong to the accessing tenant.
        let hit = self
            .allocs
            .range(..=off as u64)
            .next_back()
            .filter(|(&a, al)| off >= a as usize && off + len <= a as usize + al.len);
        match hit {
            None => Err(MemError::OutOfRange { addr, len }),
            Some((_, al)) if al.owner != owner => Err(MemError::Foreign { addr, owner }),
            Some(_) => Ok(off),
        }
    }

    /// CPU/DMA write of f32 data (kernel domain).
    pub fn write_f32(&mut self, addr: PhysAddr, data: &[f32]) -> Result<(), MemError> {
        self.write_f32_for(KERNEL_OWNER, addr, data)
    }

    /// CPU/DMA write of f32 data on behalf of `owner`.
    pub fn write_f32_for(
        &mut self,
        owner: TenantId,
        addr: PhysAddr,
        data: &[f32],
    ) -> Result<(), MemError> {
        let off = self.check(owner, addr, data.len() * 4)?;
        for (k, v) in data.iter().enumerate() {
            self.mem[off + 4 * k..off + 4 * k + 4].copy_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    /// CPU/DMA read of f32 data (kernel domain).
    pub fn read_f32(&self, addr: PhysAddr, count: usize) -> Result<Vec<f32>, MemError> {
        self.read_f32_for(KERNEL_OWNER, addr, count)
    }

    /// CPU/DMA read of f32 data on behalf of `owner`.
    pub fn read_f32_for(
        &self,
        owner: TenantId,
        addr: PhysAddr,
        count: usize,
    ) -> Result<Vec<f32>, MemError> {
        let off = self.check(owner, addr, count * 4)?;
        Ok((0..count)
            .map(|k| {
                f32::from_le_bytes(self.mem[off + 4 * k..off + 4 * k + 4].try_into().unwrap())
            })
            .collect())
    }

    pub fn write_bytes(&mut self, addr: PhysAddr, data: &[u8]) -> Result<(), MemError> {
        self.write_bytes_for(KERNEL_OWNER, addr, data)
    }

    pub fn write_bytes_for(
        &mut self,
        owner: TenantId,
        addr: PhysAddr,
        data: &[u8],
    ) -> Result<(), MemError> {
        let off = self.check(owner, addr, data.len())?;
        self.mem[off..off + data.len()].copy_from_slice(data);
        Ok(())
    }

    pub fn read_bytes(&self, addr: PhysAddr, len: usize) -> Result<Vec<u8>, MemError> {
        self.read_bytes_for(KERNEL_OWNER, addr, len)
    }

    pub fn read_bytes_for(
        &self,
        owner: TenantId,
        addr: PhysAddr,
        len: usize,
    ) -> Result<Vec<u8>, MemError> {
        let off = self.check(owner, addr, len)?;
        Ok(self.mem[off..off + len].to_vec())
    }
}

fn align_up(x: u64) -> u64 {
    (x + ALIGN - 1) & !(ALIGN - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_write_read_roundtrip() {
        let mut dm = DataManager::new(1 << 20);
        let a = dm.alloc(4096).unwrap();
        assert_eq!(a.0 % ALIGN, 0);
        assert!(a.0 >= DDR_BASE);
        let data: Vec<f32> = (0..1024).map(|i| i as f32).collect();
        dm.write_f32(a, &data).unwrap();
        assert_eq!(dm.read_f32(a, 1024).unwrap(), data);
    }

    #[test]
    fn allocations_disjoint_and_aligned() {
        let mut dm = DataManager::new(1 << 20);
        let addrs: Vec<PhysAddr> = (0..10).map(|_| dm.alloc(1000).unwrap()).collect();
        for w in addrs.windows(2) {
            assert!(w[1].0 >= w[0].0 + 1000);
            assert_eq!(w[1].0 % ALIGN, 0);
        }
    }

    #[test]
    fn free_then_reuse() {
        let mut dm = DataManager::new(16 * 4096);
        let a = dm.alloc(4096).unwrap();
        let _b = dm.alloc(4096).unwrap();
        dm.free(a).unwrap();
        let c = dm.alloc(4096).unwrap();
        assert_eq!(c, a, "first-fit should reuse the freed hole");
        assert!(matches!(dm.free(a), Ok(())));
        assert!(matches!(dm.free(a), Err(MemError::BadFree(_))));
    }

    #[test]
    fn oom_reported_with_sizes() {
        let mut dm = DataManager::new(8192);
        let _a = dm.alloc(4096).unwrap();
        let err = dm.alloc(8192).unwrap_err();
        assert!(matches!(err, MemError::OutOfMemory { requested: 8192, .. }));
    }

    #[test]
    fn out_of_bounds_access_rejected() {
        let mut dm = DataManager::new(1 << 16);
        let a = dm.alloc(64).unwrap();
        assert!(dm.write_f32(a, &vec![0.0; 17]).is_err()); // 68 bytes > 64
        assert!(dm.read_f32(PhysAddr(DDR_BASE + 60_000), 4).is_err());
        assert!(dm.read_f32(PhysAddr(0), 1).is_err()); // below DDR base
        // Interior access within an allocation is fine.
        let mid = PhysAddr(a.0 + 16);
        dm.write_f32(mid, &[1.0, 2.0]).unwrap();
        assert_eq!(dm.read_f32(mid, 2).unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn cross_tenant_access_denied_victim_intact() {
        let mut dm = DataManager::new(1 << 16);
        let victim = dm.alloc_for(1, 64).unwrap();
        dm.write_f32_for(1, victim, &[7.0; 16]).unwrap();
        // Tenant 2 can neither read, write nor free tenant 1's buffer
        // even with the exact physical address in hand.
        assert!(matches!(
            dm.read_f32_for(2, victim, 4),
            Err(MemError::Foreign { owner: 2, .. })
        ));
        assert!(matches!(
            dm.write_f32_for(2, victim, &[0.0; 4]),
            Err(MemError::Foreign { owner: 2, .. })
        ));
        assert!(matches!(dm.free_for(2, victim), Err(MemError::Foreign { owner: 2, .. })));
        // Kernel domain gets no special bypass either.
        assert!(dm.read_f32(victim, 4).is_err());
        // Victim data untouched, and the owner still works.
        assert_eq!(dm.read_f32_for(1, victim, 16).unwrap(), vec![7.0; 16]);
        assert_eq!(dm.tenant_bytes(1), 64);
        assert_eq!(dm.owner_of(victim), Some(1));
    }

    #[test]
    fn reclaim_tenant_frees_whole_arena() {
        let mut dm = DataManager::new(16 * 4096);
        let a1 = dm.alloc_for(1, 4096).unwrap();
        let _a2 = dm.alloc_for(1, 4096).unwrap();
        let b = dm.alloc_for(2, 4096).unwrap();
        assert_eq!(dm.reclaim_tenant(1), 8192);
        assert_eq!(dm.tenant_bytes(1), 0);
        assert_eq!(dm.reclaim_tenant(1), 0, "reclaim is idempotent");
        // Survivor untouched; the freed range is reusable by others.
        assert_eq!(dm.tenant_bytes(2), 4096);
        assert_eq!(dm.owner_of(b), Some(2));
        let c = dm.alloc_for(2, 4096).unwrap();
        assert_eq!(c, a1, "first-fit reuses the reclaimed hole");
    }
}
