//! The Listing-3 HLS control protocol and the per-accelerator register
//! file the generic driver programs through MMIO.

use crate::accel::Register;
use std::collections::BTreeMap;

/// Control word bits at offset 0x00 (Listing 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ControlBits;

impl ControlBits {
    pub const AP_START: u32 = 1 << 0; // Read/Write/COH
    pub const AP_DONE: u32 = 1 << 1; // Read/COR (clear on read)
    pub const AP_IDLE: u32 = 1 << 2; // Read
    pub const AP_READY: u32 = 1 << 3; // Read
    pub const AUTO_RESTART: u32 = 1 << 7; // Read/Write
}

/// The MMIO register space of one loaded accelerator.  `PartialEq`
/// lets checkpoint/restore tests assert byte-exact register-file
/// round-trips (the rollback no-partial-effect contract).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegisterFile {
    /// Operand registers by offset (64-bit pointer registers).
    values: BTreeMap<u64, u64>,
    /// Known register map (from the Listing-2 descriptor).
    map: Vec<Register>,
    control: u32,
}

impl RegisterFile {
    pub fn new(map: &[Register]) -> RegisterFile {
        RegisterFile {
            values: BTreeMap::new(),
            map: map.to_vec(),
            control: ControlBits::AP_IDLE,
        }
    }

    pub fn offset_of(&self, name: &str) -> Option<u64> {
        self.map.iter().find(|r| r.name == name).map(|r| r.offset)
    }

    /// Generic-driver write: by register *name* (the whole point of the
    /// standardised map — no per-accelerator driver code).
    pub fn write_by_name(&mut self, name: &str, value: u64) -> Result<(), String> {
        let off = self
            .offset_of(name)
            .ok_or_else(|| format!("no register named {name:?}"))?;
        self.write(off, value);
        Ok(())
    }

    pub fn read_by_name(&self, name: &str) -> Result<u64, String> {
        let off = self
            .offset_of(name)
            .ok_or_else(|| format!("no register named {name:?}"))?;
        Ok(self.read(off))
    }

    pub fn write(&mut self, offset: u64, value: u64) {
        if offset == 0 {
            // Control word: software may set AP_START / AUTO_RESTART.
            let settable = ControlBits::AP_START | ControlBits::AUTO_RESTART;
            self.control = (self.control & !settable) | (value as u32 & settable);
            if value as u32 & ControlBits::AP_START != 0 {
                self.control &= !ControlBits::AP_IDLE;
            }
        } else {
            self.values.insert(offset, value);
        }
    }

    pub fn read(&self, offset: u64) -> u64 {
        if offset == 0 {
            self.control as u64
        } else {
            self.values.get(&offset).copied().unwrap_or(0)
        }
    }

    /// Clear-on-read semantics for AP_DONE (Listing 3: "Read/COR").
    pub fn read_control_cor(&mut self) -> u32 {
        let c = self.control;
        self.control &= !ControlBits::AP_DONE;
        c
    }

    pub fn is_start(&self) -> bool {
        self.control & ControlBits::AP_START != 0
    }

    pub fn is_idle(&self) -> bool {
        self.control & ControlBits::AP_IDLE != 0
    }

    /// Hardware-side completion: ap_done pulses, ap_start self-clears
    /// (COH), ap_idle reasserts.
    pub fn complete(&mut self) {
        self.control &= !ControlBits::AP_START;
        self.control |= ControlBits::AP_DONE | ControlBits::AP_IDLE | ControlBits::AP_READY;
    }

    /// Operand values in register-map order (skipping control).
    pub fn operands(&self) -> Vec<(String, u64)> {
        self.map
            .iter()
            .filter(|r| r.offset != 0)
            .map(|r| (r.name.clone(), self.read(r.offset)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map() -> Vec<Register> {
        vec![
            Register { name: "control".into(), offset: 0 },
            Register { name: "a_op".into(), offset: 0x10 },
            Register { name: "b_op".into(), offset: 0x18 },
            Register { name: "c_out".into(), offset: 0x20 },
        ]
    }

    #[test]
    fn listing3_protocol() {
        let mut rf = RegisterFile::new(&map());
        assert!(rf.is_idle());
        assert!(!rf.is_start());
        rf.write(0, ControlBits::AP_START as u64);
        assert!(rf.is_start());
        assert!(!rf.is_idle());
        rf.complete();
        assert!(!rf.is_start()); // COH self-clear
        let c = rf.read_control_cor();
        assert!(c & ControlBits::AP_DONE != 0);
        // COR: second read sees done cleared.
        assert!(rf.read_control_cor() & ControlBits::AP_DONE == 0);
        assert!(rf.is_idle());
    }

    #[test]
    fn named_access_and_operands() {
        let mut rf = RegisterFile::new(&map());
        rf.write_by_name("a_op", 0x4000_0000).unwrap();
        rf.write_by_name("b_op", 0x4000_4000).unwrap();
        rf.write_by_name("c_out", 0x4000_8000).unwrap();
        assert_eq!(rf.read_by_name("b_op").unwrap(), 0x4000_4000);
        assert!(rf.write_by_name("nope", 1).is_err());
        let ops = rf.operands();
        assert_eq!(ops.len(), 3);
        assert_eq!(ops[0], ("a_op".to_string(), 0x4000_0000));
    }

    #[test]
    fn reserved_control_bits_ignored() {
        let mut rf = RegisterFile::new(&map());
        rf.write(0, 0xFFFF_FF00 | ControlBits::AUTO_RESTART as u64);
        // Only AP_START and AUTO_RESTART are software-settable.
        assert_eq!(
            rf.read(0) as u32 & !(ControlBits::AP_IDLE),
            ControlBits::AUTO_RESTART
        );
    }
}
