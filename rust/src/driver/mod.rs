//! Generic accelerator drivers + the data manager (§4.3).
//!
//! Because every FOS accelerator follows the standard Vivado-HLS control
//! protocol (Listing 3) and publishes its register map in the Listing-2
//! descriptor, ONE driver serves all of them — hardware developers never
//! write drivers. The data manager provides contiguous "physical" memory
//! the way the real FOS uses a CMA/udmabuf allocator.

mod regs;
mod memory;
mod cynq;

pub use cynq::{AccelSnapshot, Cynq, CynqError, LoadedAccel};
pub use memory::{DataManager, MemError, PhysAddr, TenantId, KERNEL_OWNER};
pub use regs::{ControlBits, RegisterFile};
