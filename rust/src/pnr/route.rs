//! Congestion-aware router with GoAhead-style blockers (§4.1.1/§4.1.3).
//!
//! Routing is modelled at tile granularity: each tile has a wire
//! capacity; a net occupies one unit in every tile its path crosses.
//! Nets route as L-shapes (the two orientations) and a rip-up-and-retry
//! loop resolves overflow — enough fidelity to (a) enforce the blocker
//! fence structurally and (b) expose congestion growth with utilisation,
//! which is what makes dense modules slow to compile (Table 3).
//!
//! Blockers implement the paper's isolation rules: when routing a
//! *module*, every tile outside its bbox is blocked except the interface
//! tunnel tiles; when routing the *static system*, every tile inside any
//! PR bbox is blocked except the tunnels (the "blocker macro uses all
//! local wires" trick, §4.1.1).

use super::place::Placement;
use super::netlist::Netlist;
use crate::fabric::{Device, Rect};
use std::fmt;

/// Per-tile routing capacity. UltraScale+ interconnect tiles carry on
/// the order of hundreds of wires per direction with multi-hop fan-
/// through; at our tile granularity (one L-path unit per net per tile)
/// the dense Table-3 module (81% util, ~50k two-point nets in a 48x60
/// bbox) averages ~650 net-units per tile with ~4x hotspots around the
/// interface tunnel, so 4096 is the "routable fabric" ceiling; designs
/// that exceed it are genuinely over-packed.
pub const TILE_CAPACITY: u16 = 4096;

/// Maximum rip-up iterations before declaring the design unroutable.
pub const MAX_PASSES: usize = 8;

/// A set of blocked tiles plus tunnel exceptions.
#[derive(Debug, Clone)]
pub struct Blockers {
    /// Tiles where routing is prohibited.
    blocked: Vec<bool>,
    cols: usize,
    rows: usize,
}

impl Blockers {
    pub fn none(device: &Device) -> Blockers {
        Blockers {
            blocked: vec![false; device.columns.len() * device.rows],
            cols: device.columns.len(),
            rows: device.rows,
        }
    }

    /// Block everything *outside* `bbox` (module compile), except the
    /// tunnel tiles on the bbox's right edge.
    pub fn module_fence(device: &Device, bbox: &Rect, tunnel_rows: &[usize]) -> Blockers {
        let mut b = Blockers::none(device);
        for col in 0..b.cols {
            for row in 0..b.rows {
                let inside = bbox.contains(col, row);
                let tunnel = col == bbox.c1.saturating_sub(1)
                    && tunnel_rows.iter().any(|&t| bbox.r0 + t == row);
                // Tunnels sit on the edge column and extend one tile out.
                let tunnel_out = col == bbox.c1
                    && tunnel_rows.iter().any(|&t| bbox.r0 + t == row);
                b.set(col, row, !(inside || tunnel || tunnel_out));
            }
        }
        b
    }

    /// Block everything *inside* the PR bboxes (static compile), except
    /// tunnels.
    pub fn static_fence(device: &Device, regions: &[(Rect, Vec<usize>)]) -> Blockers {
        let mut b = Blockers::none(device);
        for (bbox, tunnels) in regions {
            for col in bbox.c0..bbox.c1 {
                for row in bbox.r0..bbox.r1 {
                    let tunnel = col == bbox.c1 - 1
                        && tunnels.iter().any(|&t| bbox.r0 + t == row);
                    if !tunnel {
                        b.set(col, row, true);
                    }
                }
            }
        }
        b
    }

    fn idx(&self, col: usize, row: usize) -> usize {
        row * self.cols + col
    }

    pub fn set(&mut self, col: usize, row: usize, blocked: bool) {
        let i = self.idx(col, row);
        self.blocked[i] = blocked;
    }

    pub fn is_blocked(&self, col: usize, row: usize) -> bool {
        self.blocked[self.idx(col, row)]
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteError {
    /// A net's endpoints are separated by blocked tiles in both L
    /// orientations.
    Unroutable { net: usize },
    /// Congestion didn't resolve within MAX_PASSES.
    CongestionOverflow { overflowed_tiles: usize },
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::Unroutable { net } => write!(f, "net {net} unroutable through blockers"),
            RouteError::CongestionOverflow { overflowed_tiles } => {
                write!(f, "congestion unresolved: {overflowed_tiles} tiles over capacity")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// Routing result statistics (feed the Table 3 cost model).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RouteStats {
    pub wirelength: u64,
    pub passes: usize,
    pub max_tile_usage: u16,
    pub nets_routed: usize,
}

/// L-shaped path through tiles from a to b with the given orientation
/// (true = horizontal-first). Visits each tile once.
fn l_path(a: (u16, u16), b: (u16, u16), horiz_first: bool, mut f: impl FnMut(usize, usize) -> bool) -> bool {
    let (ac, ar) = (a.0 as i64, a.1 as i64);
    let (bc, br) = (b.0 as i64, b.1 as i64);
    let corner = if horiz_first { (bc, ar) } else { (ac, br) };
    let mut visit = |c: i64, r: i64| f(c as usize, r as usize);
    // Leg 1: a -> corner; Leg 2: corner -> b (skip corner duplicate).
    let mut ok = true;
    let step = |from: i64, to: i64| if from <= to { 1i64 } else { -1 };
    if horiz_first {
        let s = step(ac, corner.0);
        let mut c = ac;
        loop {
            ok &= visit(c, ar);
            if c == corner.0 {
                break;
            }
            c += s;
        }
        let s = step(ar, br);
        let mut r = ar;
        while r != br {
            r += s;
            ok &= visit(bc, r);
        }
    } else {
        let s = step(ar, corner.1);
        let mut r = ar;
        loop {
            ok &= visit(ac, r);
            if r == corner.1 {
                break;
            }
            r += s;
        }
        let s = step(ac, bc);
        let mut c = ac;
        while c != bc {
            c += s;
            ok &= visit(c, br);
        }
    }
    ok
}

/// Route all nets of a placed design, honouring blockers.
pub fn route(
    device: &Device,
    netlist: &Netlist,
    placement: &Placement,
    blockers: &Blockers,
) -> Result<RouteStats, RouteError> {
    let cols = device.columns.len();
    let mut usage: Vec<u16> = vec![0; cols * device.rows];
    let mut orientation: Vec<bool> = vec![true; netlist.nets.len()];
    let mut wirelength;

    // Interface nets: every interface cell must reach the tunnel exit
    // (bbox right edge, first tunnel row). Model as extra nets.
    let tunnel = (
        placement.bbox.c1.saturating_sub(1) as u16,
        (placement.bbox.r0 + 28).min(placement.bbox.r1 - 1) as u16,
    );

    let path_ok = |a: (u16, u16), b: (u16, u16), horiz: bool| -> bool {
        let mut ok = true;
        l_path(a, b, horiz, |c, r| {
            if blockers.is_blocked(c, r) {
                ok = false;
            }
            true
        });
        ok
    };

    // Pass 0: check reachability for every net under the blockers.
    let endpoints = |i: usize| -> ((u16, u16), (u16, u16)) {
        if i < netlist.nets.len() {
            let (a, b) = netlist.nets[i];
            (placement.positions[a as usize], placement.positions[b as usize])
        } else {
            let cell = netlist.interface_cells[i - netlist.nets.len()];
            (placement.positions[cell as usize], tunnel)
        }
    };
    let total_nets = netlist.nets.len() + netlist.interface_cells.len();
    orientation.resize(total_nets, true);
    for i in 0..total_nets {
        let (a, b) = endpoints(i);
        if !path_ok(a, b, true) {
            if path_ok(a, b, false) {
                orientation[i] = false;
            } else {
                return Err(RouteError::Unroutable { net: i });
            }
        }
    }

    // Congestion loop: commit all paths, then re-orient nets crossing
    // overflowed tiles.
    let mut passes = 0;
    loop {
        passes += 1;
        usage.iter_mut().for_each(|u| *u = 0);
        wirelength = 0;
        for i in 0..total_nets {
            let (a, b) = endpoints(i);
            l_path(a, b, orientation[i], |c, r| {
                usage[r * cols + c] = usage[r * cols + c].saturating_add(1);
                wirelength += 1;
                true
            });
        }
        let overflowed: Vec<usize> = usage
            .iter()
            .enumerate()
            .filter(|(_, &u)| u > TILE_CAPACITY)
            .map(|(i, _)| i)
            .collect();
        if overflowed.is_empty() {
            break;
        }
        if passes >= MAX_PASSES {
            return Err(RouteError::CongestionOverflow { overflowed_tiles: overflowed.len() });
        }
        // Flip orientation of nets whose current path crosses overflow,
        // when the flip is legal under the blockers.
        let hot: std::collections::HashSet<usize> = overflowed.into_iter().collect();
        for i in 0..total_nets {
            let (a, b) = endpoints(i);
            let mut crosses = false;
            l_path(a, b, orientation[i], |c, r| {
                if hot.contains(&(r * cols + c)) {
                    crosses = true;
                }
                true
            });
            if crosses && path_ok(a, b, !orientation[i]) {
                orientation[i] = !orientation[i];
            }
        }
    }

    Ok(RouteStats {
        wirelength,
        passes,
        max_tile_usage: usage.iter().copied().max().unwrap_or(0),
        nets_routed: total_nets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::place::place;
    use crate::fabric::{DeviceKind, Floorplan, Resources};

    fn setup(luts: usize) -> (Device, crate::fabric::PrRegion, Placement, Netlist) {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        let nl = Netlist::synthesize(
            "mod",
            &Resources { luts, ffs: luts, brams: 8, dsps: 16 },
        );
        let p = place(&fp.device, &nl, fp.regions[0].bbox).unwrap();
        (fp.device.clone(), fp.regions[0].clone(), p, nl)
    }

    #[test]
    fn routes_within_module_fence() {
        let (dev, region, p, nl) = setup(2000);
        let b = Blockers::module_fence(&dev, &region.bbox, &region.tunnel_rows);
        let stats = route(&dev, &nl, &p, &b).unwrap();
        assert!(stats.wirelength > 0);
        assert_eq!(stats.nets_routed, nl.nets.len() + nl.interface_cells.len());
    }

    #[test]
    fn fence_without_tunnel_is_unroutable() {
        let (dev, region, p, nl) = setup(500);
        // A fence with no tunnels: interface nets cannot escape... but
        // interface nets target the in-bbox tunnel tile, which is legal;
        // instead, block the whole bbox interior to prove the fence works.
        let mut b = Blockers::module_fence(&dev, &region.bbox, &[]);
        // Also block the tunnel edge column inside the bbox.
        for row in region.bbox.r0..region.bbox.r1 {
            b.set(region.bbox.c1 - 1, row, true);
        }
        assert!(route(&dev, &nl, &p, &b).is_err());
    }

    #[test]
    fn denser_design_more_congested() {
        let (dev, region, p1, nl1) = setup(1000);
        let b = Blockers::module_fence(&dev, &region.bbox, &region.tunnel_rows);
        let s1 = route(&dev, &nl1, &p1, &b).unwrap();
        let (_, _, p2, nl2) = setup(12000);
        let s2 = route(&dev, &nl2, &p2, &b).unwrap();
        assert!(s2.max_tile_usage >= s1.max_tile_usage);
        assert!(s2.wirelength > s1.wirelength);
    }

    #[test]
    fn static_fence_blocks_pr_interior() {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        let regions: Vec<_> = fp
            .regions
            .iter()
            .map(|r| (r.bbox, r.tunnel_rows.clone()))
            .collect();
        let b = Blockers::static_fence(&fp.device, &regions);
        let r0 = &fp.regions[0];
        assert!(b.is_blocked(r0.bbox.c0 + 5, r0.bbox.r0 + 5));
        // Tunnel tile stays open.
        assert!(!b.is_blocked(r0.bbox.c1 - 1, r0.bbox.r0 + 28));
        // Static area open.
        assert!(!b.is_blocked(fp.device.columns.len() - 1, 0));
    }

    #[test]
    fn l_path_visits_manhattan_tiles() {
        let mut tiles = Vec::new();
        l_path((2, 3), (5, 7), true, |c, r| {
            tiles.push((c, r));
            true
        });
        assert_eq!(tiles.len(), 4 + 4); // 4 horizontal + 4 vertical steps
        assert_eq!(tiles[0], (2, 3));
        assert_eq!(*tiles.last().unwrap(), (5, 7));
        let mut tiles2 = Vec::new();
        l_path((5, 7), (2, 3), false, |c, r| {
            tiles2.push((c, r));
            true
        });
        assert_eq!(tiles2[0], (5, 7));
        assert_eq!(*tiles2.last().unwrap(), (2, 3));
    }

    #[test]
    fn zero_length_net_single_tile() {
        let mut tiles = Vec::new();
        l_path((4, 4), (4, 4), true, |c, r| {
            tiles.push((c, r));
            true
        });
        assert_eq!(tiles, vec![(4, 4)]);
    }
}
