//! The two compilation flows and the Table 3 latency model.
//!
//! Both flows run the *real* placer/router of this crate on the module
//! netlist (structure: blockers, tunnels, capacity are enforced), and
//! report *modelled Vivado seconds* through [`CostModel`].
//!
//! ## Calibration
//!
//! The cost model is fitted to the paper's own Table 3 (Vivado 2018.2.1,
//! i7-4930K, Ultra96 shell, per-region numbers obtained by dividing the
//! Xilinx-flow totals by 3 regions):
//!
//! | module        | util | P&R/region (s) | bitgen/region (s) |
//! |---------------|------|----------------|--------------------|
//! | AES           | 0.33 | 143.1          | 58.7               |
//! | Normal Est.   | 0.63 | 249.3          | 67.1               |
//! | Black Scholes | 0.81 | 432.1          | 77.1               |
//!
//! - P&R grows superlinearly with utilisation (congestion):
//!   `t = A·exp(B·util)` with A = 66.9, B = 2.30 fits all three points
//!   within ~15%.
//! - The FOS flow pays a near-constant extra for blocker generation +
//!   relocatability-constrained routing: the paper's FOS-minus-Xilinx
//!   per-region deltas are 141.1 / 138.2 / 142.5 s — we use 140 s.
//! - Bitstream generation is linear in configuration frames written:
//!   `t = 50 + 33·util` per region; the FOS flow writes one full-device
//!   bitstream (+4 s device overhead) and extracts partials with BitMan
//!   (microseconds, measured — see the perf_bitstream bench).
//!
//! The routed congestion stats perturb the model by ±10% so that harder
//! designs genuinely take longer than the smooth fit predicts.

use super::netlist::Netlist;
use super::place::{place, PlaceError};
use super::route::{route, Blockers, RouteError, RouteStats};
use crate::bitstream::{extract, synth_full, Bitstream};
use crate::fabric::{Device, Floorplan, PrRegion};
use std::fmt;

/// Calibrated Vivado-latency model (see module docs for provenance).
#[derive(Debug, Clone)]
pub struct CostModel {
    pub pnr_base_s: f64,
    pub pnr_exp: f64,
    pub fos_constraint_overhead_s: f64,
    pub bitgen_base_s: f64,
    pub bitgen_slope_s: f64,
    pub fos_fulldev_bitgen_s: f64,
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel {
            pnr_base_s: 66.9,
            pnr_exp: 2.30,
            fos_constraint_overhead_s: 140.0,
            bitgen_base_s: 50.0,
            bitgen_slope_s: 33.0,
            fos_fulldev_bitgen_s: 4.0,
        }
    }
}

impl CostModel {
    /// Modelled per-region P&R seconds for a module at `util`, perturbed
    /// by routed congestion (`stats`).
    pub fn pnr_seconds(&self, util: f64, stats: &RouteStats) -> f64 {
        let smooth = self.pnr_base_s * (self.pnr_exp * util).exp();
        // Congestion factor: extra rip-up passes slow the router; a
        // design that converges pass 1 gets the smooth fit.
        let congestion = 1.0 + 0.05 * (stats.passes.saturating_sub(1)) as f64;
        smooth * congestion
    }

    pub fn bitgen_region_seconds(&self, util: f64) -> f64 {
        self.bitgen_base_s + self.bitgen_slope_s * util
    }
}

#[derive(Debug)]
pub enum FlowError {
    Place(PlaceError),
    Route(RouteError),
    NoRegions,
}

impl fmt::Display for FlowError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FlowError::Place(e) => write!(f, "place: {e}"),
            FlowError::Route(e) => write!(f, "route: {e}"),
            FlowError::NoRegions => write!(f, "floorplan has no PR regions"),
        }
    }
}

impl std::error::Error for FlowError {}

impl From<PlaceError> for FlowError {
    fn from(e: PlaceError) -> Self {
        FlowError::Place(e)
    }
}

impl From<RouteError> for FlowError {
    fn from(e: RouteError) -> Self {
        FlowError::Route(e)
    }
}

/// What a compile produced, with the modelled latencies.
#[derive(Debug)]
pub struct CompileReport {
    pub module: String,
    pub flow: &'static str,
    /// One partial bitstream per *target region* (Xilinx flow) or a
    /// single relocatable partial (FOS flow).
    pub partials: Vec<Bitstream>,
    pub pnr_seconds: f64,
    pub bitgen_seconds: f64,
    pub route_stats: RouteStats,
    /// Real wallclock of this simulator (for the §Perf log).
    pub sim_wallclock: std::time::Duration,
}

impl CompileReport {
    pub fn total_seconds(&self) -> f64 {
        self.pnr_seconds + self.bitgen_seconds
    }
}

fn util_of(netlist: &Netlist, device: &Device, region: &PrRegion) -> f64 {
    netlist.resources().lut_util(&region.resources(device))
}

/// Standard Xilinx PR flow: P&R + bitgen once per region (§4.1, Table 3).
pub fn compile_xilinx_pr(
    fp: &Floorplan,
    netlist: &Netlist,
    model: &CostModel,
) -> Result<CompileReport, FlowError> {
    let t0 = std::time::Instant::now();
    let first = fp.regions.first().ok_or(FlowError::NoRegions)?;
    let util = util_of(netlist, &fp.device, first);
    let mut partials = Vec::new();
    let mut pnr_seconds = 0.0;
    let mut bitgen_seconds = 0.0;
    let mut last_stats = None;
    // The Xilinx flow re-implements the module in the context of the
    // *full* static design for every region (no fence needed — the tool
    // sees everything, which is exactly why nothing is relocatable).
    for region in &fp.regions {
        let placement = place(&fp.device, netlist, region.bbox)?;
        let stats = route(&fp.device, netlist, &placement, &Blockers::none(&fp.device))?;
        pnr_seconds += model.pnr_seconds(util, &stats);
        bitgen_seconds += model.bitgen_region_seconds(util);
        // Each region gets its own, non-relocatable partial.
        let full = synth_full(&fp.device, design_id(netlist, region));
        partials.push(extract(&fp.device, &full, region).expect("aligned region"));
        last_stats = Some(stats);
    }
    Ok(CompileReport {
        module: netlist.name.clone(),
        flow: "xilinx_pr",
        partials,
        pnr_seconds,
        bitgen_seconds,
        route_stats: last_stats.unwrap(),
        sim_wallclock: t0.elapsed(),
    })
}

/// FOS decoupled flow: one fenced OOC implementation + BitMan extraction
/// → a single relocatable partial (§4.1.3, Table 3).
pub fn compile_fos(
    fp: &Floorplan,
    netlist: &Netlist,
    model: &CostModel,
) -> Result<CompileReport, FlowError> {
    let t0 = std::time::Instant::now();
    let region = fp.regions.first().ok_or(FlowError::NoRegions)?;
    let util = util_of(netlist, &fp.device, region);
    let placement = place(&fp.device, netlist, region.bbox)?;
    // The fence: nothing may route outside the bbox except via tunnels.
    let fence = Blockers::module_fence(&fp.device, &region.bbox, &region.tunnel_rows);
    let stats = route(&fp.device, netlist, &placement, &fence)?;
    let pnr_seconds = model.pnr_seconds(util, &stats) + model.fos_constraint_overhead_s;
    let bitgen_seconds = model.bitgen_region_seconds(util) + model.fos_fulldev_bitgen_s;
    // Vivado writes a full bitstream of the isolated compile; BitMan
    // extracts the region — *one* relocatable partial for all regions.
    let full = synth_full(&fp.device, design_id(netlist, region));
    let partial = extract(&fp.device, &full, region).expect("aligned region");
    Ok(CompileReport {
        module: netlist.name.clone(),
        flow: "fos",
        partials: vec![partial],
        pnr_seconds,
        bitgen_seconds,
        route_stats: stats,
        sim_wallclock: t0.elapsed(),
    })
}

fn design_id(netlist: &Netlist, region: &PrRegion) -> u64 {
    netlist
        .name
        .bytes()
        .chain(region.name.bytes())
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{DeviceKind, Resources};

    fn u96() -> Floorplan {
        Floorplan::standard(Device::new(DeviceKind::Zu3eg))
    }

    fn netlist(name: &str, util: f64) -> Netlist {
        Netlist::synthesize(
            name,
            &Resources {
                luts: (17760.0 * util) as usize,
                ffs: (35520.0 * util * 0.9) as usize,
                brams: (72.0 * util * 0.4) as usize,
                dsps: (120.0 * util * 0.3) as usize,
            },
        )
    }

    #[test]
    fn fos_beats_xilinx_for_three_regions() {
        let fp = u96();
        let model = CostModel::default();
        for (name, util, paper_speedup) in [
            ("aes", 0.33, 1.74),
            ("normal_est", 0.63, 2.07),
            ("black_scholes", 0.81, 2.34),
        ] {
            let nl = netlist(name, util);
            let xil = compile_xilinx_pr(&fp, &nl, &model).unwrap();
            let fos = compile_fos(&fp, &nl, &model).unwrap();
            let speedup = xil.total_seconds() / fos.total_seconds();
            assert!(
                (speedup - paper_speedup).abs() / paper_speedup < 0.25,
                "{name}: speedup {speedup:.2} vs paper {paper_speedup}"
            );
            // FOS produces ONE relocatable partial; Xilinx one per region.
            assert_eq!(fos.partials.len(), 1);
            assert_eq!(xil.partials.len(), 3);
        }
    }

    #[test]
    fn fos_latency_flat_in_region_count() {
        let mut fp = u96();
        let model = CostModel::default();
        let nl = netlist("aes", 0.33);
        let fos3 = compile_fos(&fp, &nl, &model).unwrap();
        fp.regions.truncate(1);
        let fos1 = compile_fos(&fp, &nl, &model).unwrap();
        assert!((fos3.total_seconds() - fos1.total_seconds()).abs() < 1e-9);
    }

    #[test]
    fn xilinx_latency_linear_in_region_count() {
        let fp = u96();
        let model = CostModel::default();
        let nl = netlist("aes", 0.33);
        let x3 = compile_xilinx_pr(&fp, &nl, &model).unwrap();
        let mut fp1 = u96();
        fp1.regions.truncate(1);
        let x1 = compile_xilinx_pr(&fp1, &nl, &model).unwrap();
        let ratio = x3.total_seconds() / x1.total_seconds();
        assert!((ratio - 3.0).abs() < 0.2, "{ratio}");
    }

    #[test]
    fn denser_modules_take_longer() {
        let fp = u96();
        let model = CostModel::default();
        let sparse = compile_fos(&fp, &netlist("a", 0.3), &model).unwrap();
        let dense = compile_fos(&fp, &netlist("b", 0.8), &model).unwrap();
        assert!(dense.pnr_seconds > sparse.pnr_seconds);
        assert!(dense.bitgen_seconds > sparse.bitgen_seconds);
    }

    #[test]
    fn fos_partial_relocates_to_all_regions() {
        use crate::bitstream::relocate;
        let fp = u96();
        let nl = netlist("aes", 0.33);
        let fos = compile_fos(&fp, &nl, &CostModel::default()).unwrap();
        for target in &fp.regions[1..] {
            relocate(&fp.device, &fos.partials[0], &fp.regions[0], target).unwrap();
        }
    }
}
