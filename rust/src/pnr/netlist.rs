//! Synthetic netlists: what "out-of-context synthesis" produces here.
//!
//! A netlist is synthesised deterministically from a resource spec
//! (LUTs/FFs/BRAMs/DSPs — the manifest's per-variant numbers): cells are
//! created to match the counts, then wired with locality-biased nets the
//! way real RTL synthesis output clusters (most nets short, a few long),
//! plus a handful of interface nets that must reach the PR tunnel.

use crate::fabric::Resources;
use crate::testutil::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CellKind {
    Lut,
    Ff,
    Bram,
    Dsp,
}

/// A synthesised module netlist.
#[derive(Debug, Clone)]
pub struct Netlist {
    pub name: String,
    pub cells: Vec<CellKind>,
    /// Two-point nets as (driver cell, sink cell).
    pub nets: Vec<(u32, u32)>,
    /// Cells that talk to the PR interface tunnel (AXI wrapper pins).
    pub interface_cells: Vec<u32>,
}

impl Netlist {
    /// Synthesise a netlist for a resource spec. Deterministic in
    /// (name, spec): the same module always synthesises identically.
    pub fn synthesize(name: &str, res: &Resources) -> Netlist {
        let seed = name
            .bytes()
            .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
        let mut rng = Rng::new(seed);

        let mut cells = Vec::with_capacity(res.luts + res.ffs + res.brams + res.dsps);
        cells.extend(std::iter::repeat(CellKind::Lut).take(res.luts));
        cells.extend(std::iter::repeat(CellKind::Ff).take(res.ffs));
        cells.extend(std::iter::repeat(CellKind::Bram).take(res.brams));
        cells.extend(std::iter::repeat(CellKind::Dsp).take(res.dsps));
        let n = cells.len() as u32;

        // ~1.3 nets per cell: 80% local (neighbourhood of 64 in synthesis
        // order — synthesis output is strongly clustered), 20% global.
        let net_count = (n as usize * 13) / 10;
        let mut nets = Vec::with_capacity(net_count);
        for _ in 0..net_count {
            let a = rng.below(n as u64) as u32;
            let b = if rng.bool(0.8) {
                let lo = a.saturating_sub(32);
                let hi = (a + 32).min(n - 1);
                lo + rng.below((hi - lo + 1) as u64) as u32
            } else {
                rng.below(n as u64) as u32
            };
            if a != b {
                nets.push((a, b));
            }
        }

        // 64 interface nets (the 32-bit AXI-Lite + 128-bit AXI pins, §4.1.2).
        let interface_cells = (0..64.min(n)).map(|k| rng.below(n as u64).max(k as u64 % n as u64) as u32).collect();

        Netlist { name: name.to_string(), cells, nets, interface_cells }
    }

    pub fn resources(&self) -> Resources {
        let mut r = Resources::ZERO;
        for c in &self.cells {
            match c {
                CellKind::Lut => r.luts += 1,
                CellKind::Ff => r.ffs += 1,
                CellKind::Bram => r.brams += 1,
                CellKind::Dsp => r.dsps += 1,
            }
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Resources {
        Resources { luts: 500, ffs: 800, brams: 4, dsps: 8 }
    }

    #[test]
    fn synthesis_matches_spec_and_is_deterministic() {
        let a = Netlist::synthesize("aes", &spec());
        assert_eq!(a.resources(), spec());
        let b = Netlist::synthesize("aes", &spec());
        assert_eq!(a.nets, b.nets);
        let c = Netlist::synthesize("dct", &spec());
        assert_ne!(a.nets, c.nets); // different module, different wiring
    }

    #[test]
    fn nets_reference_valid_cells() {
        let nl = Netlist::synthesize("x", &spec());
        let n = nl.cells.len() as u32;
        assert!(nl.nets.iter().all(|&(a, b)| a < n && b < n && a != b));
        assert!(nl.interface_cells.iter().all(|&c| c < n));
        assert!(!nl.interface_cells.is_empty());
    }

    #[test]
    fn locality_bias_present() {
        let nl = Netlist::synthesize("y", &Resources { luts: 4000, ffs: 4000, brams: 0, dsps: 0 });
        let short = nl
            .nets
            .iter()
            .filter(|&&(a, b)| (a as i64 - b as i64).abs() <= 32)
            .count();
        // ~80% of nets should be neighbourhood-local.
        assert!(short as f64 / nl.nets.len() as f64 > 0.6);
    }
}
