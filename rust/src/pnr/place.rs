//! Simulated-annealing placer over the fabric grid.
//!
//! Sites follow the column model: a CLB tile offers 8 LUT + 16 FF sites,
//! BRAM columns one BRAM36 site per 5 rows, DSP columns 2 DSP sites per
//! 5 rows. Placement is constrained to a bounding box (the PR region or
//! the combined slot) — the hard module bbox constraint of §4.1.3.

use super::netlist::{CellKind, Netlist};
use crate::fabric::{ColumnKind, Device, Rect};
use crate::testutil::Rng;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlaceError {
    /// Not enough sites of a kind inside the bbox.
    Capacity { kind: &'static str, need: usize, have: usize },
}

impl fmt::Display for PlaceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlaceError::Capacity { kind, need, have } => {
                write!(f, "placement overflow: need {need} {kind} sites, bbox has {have}")
            }
        }
    }
}

impl std::error::Error for PlaceError {}

/// A completed placement.
#[derive(Debug, Clone)]
pub struct Placement {
    pub bbox: Rect,
    /// Per-cell (col, row) tile position.
    pub positions: Vec<(u16, u16)>,
    /// Half-perimeter wirelength before and after annealing.
    pub hpwl_initial: u64,
    pub hpwl_final: u64,
    pub moves_tried: u64,
    pub moves_accepted: u64,
}

/// Enumerate sites of one kind inside a bbox.
fn sites(device: &Device, bbox: &Rect, kind: CellKind) -> Vec<(u16, u16)> {
    let mut out = Vec::new();
    for col in bbox.c0..bbox.c1 {
        let ck = device.columns[col];
        for row in bbox.r0..bbox.r1 {
            let per_tile = match (ck, kind) {
                (ColumnKind::Clb, CellKind::Lut) => 8,
                (ColumnKind::Clb, CellKind::Ff) => 16,
                (ColumnKind::Bram, CellKind::Bram) => usize::from(row % 5 == 0),
                (ColumnKind::Dsp, CellKind::Dsp) => usize::from(row % 5 == 0 || row % 5 == 2),
                _ => 0,
            };
            for _ in 0..per_tile {
                out.push((col as u16, row as u16));
            }
        }
    }
    out
}

fn hpwl(netlist: &Netlist, pos: &[(u16, u16)]) -> u64 {
    netlist
        .nets
        .iter()
        .map(|&(a, b)| {
            let (ac, ar) = pos[a as usize];
            let (bc, br) = pos[b as usize];
            (ac.abs_diff(bc) as u64) + (ar.abs_diff(br) as u64)
        })
        .sum()
}

/// Place a netlist inside `bbox` on `device`.
pub fn place(device: &Device, netlist: &Netlist, bbox: Rect) -> Result<Placement, PlaceError> {
    // Group cell indices by kind and check capacity.
    let kinds = [CellKind::Lut, CellKind::Ff, CellKind::Bram, CellKind::Dsp];
    let names = ["LUT", "FF", "BRAM", "DSP"];
    let mut positions = vec![(0u16, 0u16); netlist.cells.len()];
    let mut site_pools: Vec<Vec<(u16, u16)>> = Vec::new();
    let mut cell_groups: Vec<Vec<u32>> = Vec::new();

    for (k, kind) in kinds.iter().enumerate() {
        let cells: Vec<u32> = netlist
            .cells
            .iter()
            .enumerate()
            .filter(|(_, c)| *c == kind)
            .map(|(i, _)| i as u32)
            .collect();
        let pool = sites(device, &bbox, *kind);
        if cells.len() > pool.len() {
            return Err(PlaceError::Capacity {
                kind: names[k],
                need: cells.len(),
                have: pool.len(),
            });
        }
        cell_groups.push(cells);
        site_pools.push(pool);
    }

    // Initial placement: scan order (synthesis-order locality maps to
    // spatial locality, a decent SA starting point).
    let mut rng = Rng::new(0xF05);
    for (group, pool) in cell_groups.iter().zip(&site_pools) {
        for (i, &cell) in group.iter().enumerate() {
            positions[cell as usize] = pool[i];
        }
    }
    let hpwl_initial = hpwl(netlist, &positions);

    // Annealing: swap two same-kind cells, or move a cell to a spare
    // site; accept improving moves always, worsening with e^{-d/T}.
    let mut cur = hpwl_initial as i64;
    let moves = (netlist.cells.len() as u64 * 8).clamp(2_000, 200_000);
    let mut accepted = 0u64;
    // Per-cell incident net index for delta evaluation.
    let mut incident: Vec<Vec<u32>> = vec![Vec::new(); netlist.cells.len()];
    for (ni, &(a, b)) in netlist.nets.iter().enumerate() {
        incident[a as usize].push(ni as u32);
        incident[b as usize].push(ni as u32);
    }
    let net_len = |net: u32, pos: &[(u16, u16)]| -> i64 {
        let (a, b) = netlist.nets[net as usize];
        let (ac, ar) = pos[a as usize];
        let (bc, br) = pos[b as usize];
        (ac.abs_diff(bc) as i64) + (ar.abs_diff(br) as i64)
    };

    for step in 0..moves {
        let t = 8.0 * (1.0 - step as f64 / moves as f64) + 0.05;
        // Pick a kind weighted by population, then two cells of it.
        let g = loop {
            let g = rng.below(4) as usize;
            if cell_groups[g].len() >= 2 {
                break g;
            }
        };
        let ga = *rng.pick(&cell_groups[g]) as usize;
        let gb = *rng.pick(&cell_groups[g]) as usize;
        if ga == gb {
            continue;
        }
        let before: i64 = incident[ga].iter().chain(&incident[gb]).map(|&n| net_len(n, &positions)).sum();
        positions.swap(ga, gb);
        let after: i64 = incident[ga].iter().chain(&incident[gb]).map(|&n| net_len(n, &positions)).sum();
        let delta = after - before;
        if delta <= 0 || rng.f64() < (-(delta as f64) / t).exp() {
            cur += delta;
            accepted += 1;
        } else {
            positions.swap(ga, gb); // revert
        }
    }

    debug_assert_eq!(cur, hpwl(netlist, &positions) as i64);
    Ok(Placement {
        bbox,
        positions,
        hpwl_initial,
        hpwl_final: cur as u64,
        moves_tried: moves,
        moves_accepted: accepted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{DeviceKind, Floorplan, Resources};

    fn region_bbox() -> (Device, Rect) {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        (fp.device.clone(), fp.regions[0].bbox)
    }

    #[test]
    fn placement_fits_and_improves() {
        let (dev, bbox) = region_bbox();
        let nl = Netlist::synthesize(
            "aes",
            &Resources { luts: 5860, ffs: 10548, brams: 0, dsps: 18 },
        );
        let p = place(&dev, &nl, bbox).unwrap();
        assert!(p.hpwl_final <= p.hpwl_initial, "{} > {}", p.hpwl_final, p.hpwl_initial);
        // Every cell inside the bbox.
        assert!(p
            .positions
            .iter()
            .all(|&(c, r)| bbox.contains(c as usize, r as usize)));
        assert!(p.moves_accepted > 0);
    }

    #[test]
    fn capacity_overflow_rejected() {
        let (dev, bbox) = region_bbox();
        let nl = Netlist::synthesize(
            "huge",
            &Resources { luts: 20_000, ffs: 0, brams: 0, dsps: 0 },
        );
        assert!(matches!(
            place(&dev, &nl, bbox),
            Err(PlaceError::Capacity { kind: "LUT", .. })
        ));
    }

    #[test]
    fn bram_dsp_sites_counted_correctly() {
        let (dev, bbox) = region_bbox();
        // Exactly the Table-1 per-region capacity must fit.
        let nl = Netlist::synthesize(
            "full",
            &Resources { luts: 17760, ffs: 35520, brams: 72, dsps: 120 },
        );
        assert!(place(&dev, &nl, bbox).is_ok());
        let nl2 = Netlist::synthesize(
            "toomanybram",
            &Resources { luts: 0, ffs: 0, brams: 73, dsps: 0 },
        );
        assert!(place(&dev, &nl2, bbox).is_err());
    }

    #[test]
    fn combined_region_doubles_capacity() {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        let combined = Rect {
            c0: fp.regions[0].bbox.c0,
            c1: fp.regions[0].bbox.c1,
            r0: fp.regions[0].bbox.r0,
            r1: fp.regions[1].bbox.r1,
        };
        let nl = Netlist::synthesize(
            "big",
            &Resources { luts: 30_000, ffs: 60_000, brams: 100, dsps: 200 },
        );
        assert!(place(&fp.device, &nl, fp.regions[0].bbox).is_err());
        assert!(place(&fp.device, &nl, combined).is_ok());
    }

    #[test]
    fn deterministic_given_same_inputs() {
        let (dev, bbox) = region_bbox();
        let nl = Netlist::synthesize(
            "det",
            &Resources { luts: 1000, ffs: 1500, brams: 8, dsps: 12 },
        );
        let a = place(&dev, &nl, bbox).unwrap();
        let b = place(&dev, &nl, bbox).unwrap();
        assert_eq!(a.positions, b.positions);
        assert_eq!(a.hpwl_final, b.hpwl_final);
    }
}
