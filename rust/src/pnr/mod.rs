//! Place & route simulator — the decoupled compilation flow (§4.1).
//!
//! Two flows are modelled, matching the paper's Table 3 comparison:
//!
//! - **Xilinx PR flow**: the module is implemented as an increment to a
//!   specific shell, once *per partial region* (N regions → N P&R runs →
//!   N bitstreams).
//! - **FOS flow**: the module is implemented *once*, out-of-context,
//!   against a PR wrapper template with GoAhead-style blocker macros;
//!   the resulting full bitstream is handed to BitMan, which extracts a
//!   relocatable partial that serves every region.
//!
//! The placer (simulated annealing over the fabric grid) and router
//! (L-shaped route with congestion rip-up, honouring blockers and
//! interface tunnels) run for real on synthesised netlists — they
//! enforce the §4.1 isolation rules structurally. Tool *latency* is a
//! calibrated model (see [`CostModel`]) because Vivado's wallclock
//! obviously cannot be reproduced by a simulator; the calibration
//! constants and their provenance are documented on the type.

mod netlist;
mod place;
mod route;
mod flow;

pub use flow::{compile_fos, compile_xilinx_pr, CompileReport, CostModel, FlowError};
pub use netlist::{CellKind, Netlist};
pub use place::{place, Placement, PlaceError};
pub use route::{route, Blockers, RouteError, RouteStats};
