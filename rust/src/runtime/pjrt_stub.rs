//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The vendored build environment does not ship the `xla` crate (the
//! Rust bindings over xla_extension), so this module mirrors the exact
//! API surface `runtime` uses and fails fast at client construction.
//! Every consumer already handles that path gracefully: the executor
//! worker answers each request with the construction error, the
//! scheduler core keeps making (and logging) decisions, and the
//! latency models stay fully functional — only *real* tile compute is
//! unavailable.
//!
//! To run with genuine PJRT compute, vendor the real `xla` crate and
//! swap the `use self::pjrt_stub as xla;` alias in `runtime/mod.rs`
//! for `use xla;` — no other code changes are required, the types and
//! signatures below match the real bindings one-to-one.

use std::fmt;

const UNAVAILABLE: &str =
    "PJRT backend unavailable: built against the offline xla stub (see runtime/pjrt_stub.rs)";

/// Mirror of the binding crate's error type.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stand-in for `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(UNAVAILABLE.into()))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE.into()))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_a_descriptive_error() {
        let err = PjRtClient::cpu().err().unwrap().to_string();
        assert!(err.contains("stub"), "{err}");
    }
}
