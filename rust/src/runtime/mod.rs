//! PJRT runtime: loads the AOT HLO artifacts and executes accelerator
//! compute — the bridge between the Rust request path and the
//! python-authored (but never python-executed) L2/L1 layers.
//!
//! Interchange is HLO *text* (`artifacts/*.hlo.txt`): jax ≥ 0.5 emits
//! HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids (see gen_hlo notes in
//! /opt/xla-example). Each variant compiles once on first use and is
//! cached for the lifetime of the executor.
//!
//! The PJRT client is owned by a dedicated worker thread (the xla
//! wrapper types are not Sync, and a single compile/execute stream
//! matches the single configuration port of the simulated fabric);
//! [`Executor`] handles are cheap to clone and thread-safe.

use crate::accel::Catalog;
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

mod pjrt_stub;
// The offline vendor set has no `xla` crate; the stub mirrors its API
// and fails fast at client construction (every caller handles that).
// Swap this alias for the real bindings to enable genuine compute.
use self::pjrt_stub as xla;

/// An execution request's reply.
type Reply<T> = mpsc::Sender<T>;

enum Req {
    Execute {
        variant: String,
        inputs: Vec<Vec<f32>>,
        reply: Reply<Result<ExecOutput, String>>,
    },
    Preload {
        variant: String,
        reply: Reply<Result<Duration, String>>,
    },
    Stats {
        reply: Reply<ExecStats>,
    },
    Stop,
}

/// One execution's outputs + timing.
#[derive(Debug, Clone)]
pub struct ExecOutput {
    pub outputs: Vec<Vec<f32>>,
    pub exec_wallclock: Duration,
}

#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub compiles: u64,
    pub compile_time: Duration,
    pub exec_time: Duration,
}

/// Thread-safe handle to the PJRT worker.
#[derive(Clone)]
pub struct Executor {
    tx: mpsc::Sender<Req>,
}

impl Executor {
    /// Spawn the worker around a catalog.
    pub fn new(catalog: Catalog) -> Executor {
        let (tx, rx) = mpsc::channel::<Req>();
        std::thread::Builder::new()
            .name("fos-pjrt".into())
            .spawn(move || worker(catalog, rx))
            .expect("spawn pjrt worker");
        Executor { tx }
    }

    /// Execute one work item on an accelerator variant. `inputs` are
    /// flattened f32 buffers matching the catalogued shapes.
    pub fn execute(
        &self,
        variant: &str,
        inputs: Vec<Vec<f32>>,
    ) -> Result<ExecOutput, String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Execute { variant: variant.to_string(), inputs, reply })
            .map_err(|_| "executor stopped".to_string())?;
        rx.recv().map_err(|_| "executor died".to_string())?
    }

    /// Compile a variant ahead of time; returns compile latency.
    pub fn preload(&self, variant: &str) -> Result<Duration, String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Preload { variant: variant.to_string(), reply })
            .map_err(|_| "executor stopped".to_string())?;
        rx.recv().map_err(|_| "executor died".to_string())?
    }

    pub fn stats(&self) -> ExecStats {
        let (reply, rx) = mpsc::channel();
        if self.tx.send(Req::Stats { reply }).is_err() {
            return ExecStats::default();
        }
        rx.recv().unwrap_or_default()
    }

    pub fn stop(&self) {
        let _ = self.tx.send(Req::Stop);
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    in_shapes: Vec<Vec<i64>>,
    out_elems: Vec<usize>,
}

fn worker(catalog: Catalog, rx: mpsc::Receiver<Req>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the construction error.
            let msg = format!("pjrt cpu client: {e}");
            while let Ok(req) = rx.recv() {
                match req {
                    Req::Execute { reply, .. } => {
                        let _ = reply.send(Err(msg.clone()));
                    }
                    Req::Preload { reply, .. } => {
                        let _ = reply.send(Err(msg.clone()));
                    }
                    Req::Stats { reply } => {
                        let _ = reply.send(ExecStats::default());
                    }
                    Req::Stop => break,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, Compiled> = HashMap::new();
    let mut stats = ExecStats::default();

    while let Ok(req) = rx.recv() {
        match req {
            Req::Stop => break,
            Req::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Req::Preload { variant, reply } => {
                let t0 = Instant::now();
                let r = ensure(&client, &catalog, &mut cache, &variant, &mut stats)
                    .map(|_| t0.elapsed());
                let _ = reply.send(r);
            }
            Req::Execute { variant, inputs, reply } => {
                let r = (|| {
                    ensure(&client, &catalog, &mut cache, &variant, &mut stats)?;
                    let c = cache.get(&variant).unwrap();
                    if inputs.len() != c.in_shapes.len() {
                        return Err(format!(
                            "{variant}: expected {} inputs, got {}",
                            c.in_shapes.len(),
                            inputs.len()
                        ));
                    }
                    let mut literals = Vec::with_capacity(inputs.len());
                    for (buf, shape) in inputs.iter().zip(&c.in_shapes) {
                        let want: i64 = shape.iter().product();
                        if buf.len() as i64 != want {
                            return Err(format!(
                                "{variant}: input length {} != shape {:?}",
                                buf.len(),
                                shape
                            ));
                        }
                        let lit = xla::Literal::vec1(buf)
                            .reshape(shape)
                            .map_err(|e| format!("reshape: {e}"))?;
                        literals.push(lit);
                    }
                    let t0 = Instant::now();
                    let result = c
                        .exe
                        .execute::<xla::Literal>(&literals)
                        .map_err(|e| format!("execute: {e}"))?;
                    let root = result[0][0]
                        .to_literal_sync()
                        .map_err(|e| format!("to_literal: {e}"))?;
                    // aot.py lowers with return_tuple=True; all catalogued
                    // accelerators return a 1-tuple.
                    let out = root.to_tuple1().map_err(|e| format!("tuple: {e}"))?;
                    let values = out.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))?;
                    let exec_wallclock = t0.elapsed();
                    stats.executions += 1;
                    stats.exec_time += exec_wallclock;
                    if values.len() != c.out_elems[0] {
                        return Err(format!(
                            "{variant}: output length {} != expected {}",
                            values.len(),
                            c.out_elems[0]
                        ));
                    }
                    Ok(ExecOutput { outputs: vec![values], exec_wallclock })
                })();
                let _ = reply.send(r);
            }
        }
    }
}

fn ensure(
    client: &xla::PjRtClient,
    catalog: &Catalog,
    cache: &mut HashMap<String, Compiled>,
    variant: &str,
    stats: &mut ExecStats,
) -> Result<(), String> {
    if cache.contains_key(variant) {
        return Ok(());
    }
    let (accel, v) = catalog
        .accelerators
        .iter()
        .find_map(|a| a.variant(variant).map(|v| (a, v)))
        .ok_or_else(|| format!("unknown variant {variant:?}"))?;
    let path = catalog.hlo_path(v);
    let t0 = Instant::now();
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or("non-utf8 path")?,
    )
    .map_err(|e| format!("parse {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).map_err(|e| format!("compile {variant}: {e}"))?;
    stats.compiles += 1;
    stats.compile_time += t0.elapsed();
    cache.insert(
        variant.to_string(),
        Compiled {
            exe,
            in_shapes: accel
                .inputs
                .iter()
                .map(|t| t.shape.iter().map(|&d| d as i64).collect())
                .collect(),
            out_elems: accel.outputs.iter().map(|t| t.elements()).collect(),
        },
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;
    use std::sync::OnceLock;

    // One executor for the whole test binary — PJRT client construction
    // is expensive and the worker serialises execution anyway.
    static EXEC_CELL: OnceLock<Executor> = OnceLock::new();

    fn exec() -> &'static Executor {
        EXEC_CELL.get_or_init(|| Executor::new(Catalog::load_default().unwrap()))
    }

    #[test]
    fn vadd_computes_real_numbers() {
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let mut rng = Rng::new(1);
        let a: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
        let out = exec().execute("vadd_v1", vec![a.clone(), b.clone()]).unwrap();
        assert_eq!(out.outputs[0].len(), 4096);
        for k in 0..4096 {
            assert!((out.outputs[0][k] - (a[k] + b[k])).abs() < 1e-5);
        }
    }

    #[test]
    fn variants_agree_numerically() {
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        // Resource-elastic replacement must preserve semantics (§4.4.2).
        let mut rng = Rng::new(2);
        let img: Vec<f32> = (0..128 * 128).map(|_| rng.normal()).collect();
        let v1 = exec().execute("sobel_v1", vec![img.clone()]).unwrap();
        let v2 = exec().execute("sobel_v2", vec![img]).unwrap();
        for (a, b) in v1.outputs[0].iter().zip(&v2.outputs[0]) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn mm_matches_cpu_reference() {
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let mut rng = Rng::new(3);
        let a: Vec<f32> = (0..64 * 64).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..64 * 64).map(|_| rng.normal()).collect();
        let out = exec().execute("mm_v1", vec![a.clone(), b.clone()]).unwrap();
        for i in [0usize, 7, 63] {
            for j in [0usize, 31, 63] {
                let want: f32 = (0..64).map(|k| a[i * 64 + k] * b[k * 64 + j]).sum();
                let got = out.outputs[0][i * 64 + j];
                assert!((got - want).abs() < 1e-2, "({i},{j}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn shape_validation() {
        assert!(exec().execute("vadd_v1", vec![vec![0.0; 10]]).is_err());
        assert!(exec()
            .execute("vadd_v1", vec![vec![0.0; 10], vec![0.0; 4096]])
            .is_err());
        assert!(exec().execute("no_such_variant", vec![]).is_err());
    }

    #[test]
    fn preload_then_execute_is_fast_path() {
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let lat = exec().preload("dct_v1").unwrap();
        let _ = lat; // first compile latency (can be ~ms..s)
        let stats_before = exec().stats();
        let img: Vec<f32> = vec![1.0; 64 * 64];
        exec().execute("dct_v1", vec![img]).unwrap();
        let stats_after = exec().stats();
        // No recompile on the execute.
        assert_eq!(stats_after.compiles, stats_before.compiles);
        assert_eq!(stats_after.executions, stats_before.executions + 1);
    }
}
