//! # The cluster layer — N boards, one scheduler (§5's heterogeneous
//! evaluation scaled out)
//!
//! FOS evaluates per-board (Ultra96, ZCU102); the roadmap's north star
//! is a production system sharding heavy traffic across many backends.
//! This module is the layer above [`SchedCore`] that makes that real:
//! a [`ClusterCore`] owns one scheduler shard per board — each with its
//! *own* fabric model and [`CostModel`](super::core::CostModel), so
//! heterogeneous boards coexist — and a pluggable [`PlacementPolicy`]
//! decides which board every acceleration request lands on.
//!
//! The same two-harness architecture as the per-board core applies:
//! the discrete-event simulator ([`super::simulate_cluster`]) and the
//! live daemon (one `Cynq` per board) both drive this state machine,
//! and the per-shard decision sequences must match verbatim for the
//! same trace (`tests/cluster_parity.rs`).
//!
//! ## Placement policies
//!
//! A policy sees a read-only [`ShardView`] per board — residency
//! (which accelerators are configured where), queued-tile backlog and
//! running count — and routes one [`RouteReq`]:
//!
//! - [`RoundRobin`] — the baseline: boards in rotation, blind to state.
//! - [`LeastLoaded`] — the board with the smallest backlog + running
//!   load (ties to the lowest index).
//! - [`Locality`] — prefer boards whose regions *already hold* the
//!   request's accelerator (no partial reconfiguration on dispatch),
//!   falling back to least-loaded when nothing is resident or every
//!   resident board's backlog exceeds [`Locality::backlog_limit`].
//!
//! ## Work stealing
//!
//! Routing happens at admission; load changes afterwards.  To keep a
//! drained board from idling while another shard's queue is deep, the
//! harness calls [`ClusterCore::steal_into`] before each board's
//! scheduling round: a fully idle shard (no queue, nothing running)
//! pulls the most recently queued request from the shard with the
//! largest backlog above the steal threshold
//! ([`ClusterCore::with_steal_threshold`]).  Requests
//! carrying a checkpoint are never stolen — their register-file
//! snapshot lives on the donor board's hardware.  Both harnesses call
//! the hook at the same point in the round lifecycle, so stealing
//! never breaks decision parity.

use super::core::{
    Checkpoint, Decision, Policy, RegionMap, Request, SchedCore, SchedCounters, Sym,
    TenantSchedCounters,
};
use crate::accel::Catalog;
use crate::shell::{Shell, ShellBoard};
use std::collections::{BTreeMap, VecDeque};

/// Default backlog (queued tiles) past which an overloaded shard
/// becomes a work-stealing donor, and past which [`Locality`] stops
/// packing a resident board.
pub const DEFAULT_STEAL_THRESHOLD: usize = 32;

/// Merged-log ring cap (same order as the per-shard cap): bounded for
/// a long-lived daemon, plenty for tests and benches.
const MERGED_LOG_CAP: usize = 65_536;

/// Consecutive reconfiguration failures of one accelerator tolerated
/// (with exponential backoff) before the request is surfaced as a
/// structured rejection.
pub const DEFAULT_RECONFIG_FAIL_CAP: u32 = 3;

/// Base virtual backoff before a failed reconfiguration is retried;
/// doubles per consecutive failure of the same accelerator.
pub const RETRY_BACKOFF_BASE_NS: u64 = 1_000_000;

/// One board's health state (the failure-domain lifecycle):
/// `Healthy → Draining` (operator drain: no new routing, running work
/// finishes), `Healthy/Draining → Down` (failure: running + queued
/// work migrates to healthy shards), `→ Healthy` again via
/// [`ClusterCore::revive_board`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoardHealth {
    Healthy,
    Draining,
    Down,
}

impl BoardHealth {
    pub fn name(&self) -> &'static str {
        match self {
            BoardHealth::Healthy => "healthy",
            BoardHealth::Draining => "draining",
            BoardHealth::Down => "down",
        }
    }
}

/// What [`ClusterCore::reconfig_outcome`] decided about a failed
/// reconfiguration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailDisposition {
    /// Parked for an exponential-backoff retry: the harness must
    /// schedule a [`ClusterCore::release_retries`] wake-up at `at_ns`.
    Retry { at_ns: u64 },
    /// Retry cap spent: the request is in the shard's rejected buffer
    /// (drained by the usual `take_rejected` sweep).
    Rejected,
}

/// A progress record that changed shards during failover: the daemon
/// mirrors the move in its per-board register-file snapshot stores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MovedCkpt {
    /// Harness job token of the owning request.
    pub job: u64,
    /// `(board, checkpoint id)` of the snapshot's previous home;
    /// `None` when the harness parked it at drain time (no healthy
    /// board) keyed by `job`.
    pub from: Option<(usize, u64)>,
    pub to: usize,
    pub new_ckpt: u64,
}

/// One running dispatch drained off a failed board: the daemon runs
/// the completed slice, snapshots the accelerator, and stores the
/// snapshot under `(to, new_ckpt)` — or keyed by `job` when the drain
/// found no healthy board yet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DrainedRun {
    /// Anchor the dispatch was running at on the failed board.
    pub anchor: usize,
    pub job: u64,
    /// Tiles whose progress the checkpoint preserves (0 = plain
    /// re-run; no snapshot needed).
    pub done: usize,
    /// Target board the remainder migrated to (`None` = parked).
    pub to: Option<usize>,
    /// Checkpoint id the target shard assigned (`None` when `done == 0`
    /// or the remainder is parked).
    pub new_ckpt: Option<u64>,
}

/// Everything a harness must mirror after
/// [`ClusterCore::mark_board_down`].
#[derive(Debug, Clone, Default)]
pub struct FailoverReport {
    /// Running dispatches checkpointed at the failure.
    pub drained: Vec<DrainedRun>,
    /// Progress records of *queued* remainders that moved shards.
    pub moved_ckpts: Vec<MovedCkpt>,
    /// `(job token, target board)` of every migrated request.
    pub migrated_jobs: Vec<(u64, usize)>,
}

/// Result of one [`ClusterCore::release_retries`] sweep.
#[derive(Debug, Clone, Default)]
pub struct RetryOutcome {
    /// Requests re-injected into a shard.
    pub released: usize,
    /// Parked progress records adopted by a shard (daemon: move the
    /// job-keyed snapshot into the target board's store).
    pub moved_ckpts: Vec<MovedCkpt>,
}

/// A request waiting out a reconfiguration backoff — or waiting for
/// any board to be healthy again (`ckpt` carries a migrated progress
/// record drained while the whole cluster was down).
struct Parked {
    at_ns: u64,
    origin: usize,
    req: Request,
    ckpt: Option<Checkpoint>,
    /// Where the daemon's register-file snapshot for `ckpt` lives:
    /// `Some((board, old id))` = still in that board's store, `None` =
    /// the harness parked it keyed by job (a running dispatch drained
    /// while no board was healthy).  Carried into the [`MovedCkpt`]
    /// emitted at release so the daemon moves the right snapshot.
    snap_home: Option<(usize, u64)>,
}

/// Built-in placement policy selector (the cluster analogue of
/// [`Policy`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// Boards in rotation, blind to residency and load.
    RoundRobin,
    /// Smallest backlog + running load wins.
    LeastLoaded,
    /// Bitstream-residency affinity with least-loaded fallback.
    Locality,
}

impl PlacementKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementKind::RoundRobin => "round-robin",
            PlacementKind::LeastLoaded => "least-loaded",
            PlacementKind::Locality => "locality",
        }
    }

    fn instantiate(self) -> Box<dyn PlacementPolicy> {
        match self {
            PlacementKind::RoundRobin => Box::<RoundRobin>::default(),
            PlacementKind::LeastLoaded => Box::<LeastLoaded>::default(),
            PlacementKind::Locality => Box::<Locality>::default(),
        }
    }
}

/// Read-only per-shard state handed to placement policies.
pub struct ShardView<'a> {
    pub board: ShellBoard,
    /// The shard's region map (residency + busy flags).
    pub regions: &'a RegionMap,
    /// Queued tiles across every user of this shard.
    pub backlog_tiles: usize,
    /// Queued requests.
    pub pending: usize,
    /// In-flight dispatches.
    pub running: usize,
}

impl ShardView<'_> {
    /// An instance of `accel` is configured somewhere on this board
    /// (idle or busy) — dispatching there can reuse it or at least
    /// avoid a cold load later.
    pub fn holds(&self, accel: Sym) -> bool {
        self.regions.has_resident(accel)
    }

    /// Scalar load signal: queued tiles plus in-flight dispatches.
    pub fn load(&self) -> usize {
        self.backlog_tiles + self.running
    }
}

/// The request a placement policy is asked to route.
pub struct RouteReq {
    pub user: usize,
    /// Tenant the request is accounted to (defaults to `user`) — lets
    /// tenant-share-aware placements keep one tenant's requests from
    /// crowding a single board.
    pub tenant: usize,
    /// The tenant's QoS weight ([`ClusterCore::set_tenant_weight`]).
    pub weight: u32,
    /// Interned accelerator symbol (shared across every shard — all
    /// cores derive the same table from the same catalog).
    pub accel: Sym,
    pub tiles: usize,
}

/// A pluggable board-placement strategy.  Must be deterministic for a
/// given (shard states, request) pair — both harnesses route at
/// admission and their decisions must agree (cluster parity).
pub trait PlacementPolicy: Send {
    /// Stable identifier (reporting + daemon configuration).
    fn name(&self) -> &'static str;

    /// Board index for `req`.  `shards` is never empty; the returned
    /// index is clamped by the caller.
    fn route(&mut self, shards: &[ShardView<'_>], req: &RouteReq) -> usize;
}

/// Boards in strict rotation — the baseline every smarter policy is
/// judged against (fig23).
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl PlacementPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, shards: &[ShardView<'_>], _req: &RouteReq) -> usize {
        let b = self.next % shards.len();
        self.next = (b + 1) % shards.len();
        b
    }
}

/// The board with the smallest backlog + running load (ties break to
/// the lowest index for determinism).
#[derive(Debug, Default)]
pub struct LeastLoaded;

fn least_loaded(shards: &[ShardView<'_>]) -> usize {
    shards
        .iter()
        .enumerate()
        .min_by_key(|(i, s)| (s.load(), *i))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

impl PlacementPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(&mut self, shards: &[ShardView<'_>], _req: &RouteReq) -> usize {
        least_loaded(shards)
    }
}

/// Bitstream-residency affinity: prefer the least-loaded board that
/// already holds the request's accelerator — dispatching there avoids
/// a partial reconfiguration — unless every such board's backlog
/// exceeds [`Locality::backlog_limit`], in which case fall back to
/// least-loaded (the spill then seeds residency on a fresh board, and
/// work stealing drains any imbalance that still builds up).
#[derive(Debug)]
pub struct Locality {
    /// Queued-tile backlog past which a resident board is considered
    /// saturated and the request spills to the least-loaded board.
    pub backlog_limit: usize,
}

impl Default for Locality {
    fn default() -> Locality {
        Locality { backlog_limit: DEFAULT_STEAL_THRESHOLD }
    }
}

impl PlacementPolicy for Locality {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn route(&mut self, shards: &[ShardView<'_>], req: &RouteReq) -> usize {
        let resident = shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.holds(req.accel) && s.backlog_tiles < self.backlog_limit)
            .min_by_key(|(i, s)| (s.load(), *i))
            .map(|(i, _)| i);
        resident.unwrap_or_else(|| least_loaded(shards))
    }
}

/// Cluster-level counters (the per-shard [`SchedCounters`] live in
/// each shard's core).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ClusterCounters {
    /// Requests routed to a board at admission.
    pub routed: u64,
    /// Requests moved between shards by work stealing.
    pub steals: u64,
    /// Boards that failed over ([`ClusterCore::mark_board_down`]).
    pub failovers: u64,
    /// Requests migrated off a failed board (running *and* queued).
    pub migrations: u64,
    /// Virtual ns of execution destroyed by faults (failed runs, plus
    /// the checkpoint-unpreserved slice of every failover drain).
    pub lost_ns: u64,
    /// Reconfiguration attempts that failed (injected or real).
    pub reconfig_failures: u64,
    /// Failed reconfigurations parked for a backoff retry.
    pub reconfig_retries: u64,
    /// Requests surfaced as structured rejections at the retry cap.
    pub reconfig_rejections: u64,
    /// Dispatches whose execution failed transiently and re-queued.
    pub run_faults: u64,
}

struct Shard {
    board: ShellBoard,
    core: SchedCore,
}

/// N per-board scheduler shards behind one placement policy — the
/// state machine both the cluster simulator and the multi-fabric
/// daemon drive.  All per-board scheduling intelligence stays in each
/// shard's [`SchedCore`]; this type owns only *routing* (admission),
/// *stealing* (rebalance) and the merged decision log.
pub struct ClusterCore {
    shards: Vec<Shard>,
    placement: Box<dyn PlacementPolicy>,
    steal_threshold: usize,
    counters: ClusterCounters,
    /// Per-tenant QoS weights, mirrored into every shard and handed to
    /// the placement policy through [`RouteReq`].
    tenant_weights: BTreeMap<usize, u32>,
    /// (board, decision) in global dispatch order, ring-capped.
    merged: VecDeque<(usize, Decision)>,
    merged_cap: usize,
    merged_dropped: u64,
    /// Per-board health (the failure-domain lifecycle).
    health: Vec<BoardHealth>,
    /// Consecutive reconfiguration-failure streak per accelerator
    /// (reset by the first success), driving backoff + the cap.
    reconfig_failures: BTreeMap<Sym, u32>,
    reconfig_fail_cap: u32,
    /// Requests parked for a backoff retry or the next revival.
    parked: Vec<Parked>,
    /// `false` = drop-and-resubmit baseline: failover migrates full
    /// requests instead of checkpointed remainders (the comparison arm
    /// the fig23-style failover assertion beats).
    checkpoint_migration: bool,
}

impl ClusterCore {
    /// Build a cluster of `boards` (one shard per entry, heterogeneous
    /// mixes welcome) with a built-in placement policy.
    pub fn new(
        boards: &[ShellBoard],
        catalog: &Catalog,
        default: Policy,
        placement: PlacementKind,
    ) -> ClusterCore {
        Self::with_placement(boards, catalog, default, placement.instantiate())
    }

    /// [`ClusterCore::new`] with a custom [`PlacementPolicy`].
    pub fn with_placement(
        boards: &[ShellBoard],
        catalog: &Catalog,
        default: Policy,
        placement: Box<dyn PlacementPolicy>,
    ) -> ClusterCore {
        assert!(!boards.is_empty(), "a cluster needs at least one board");
        ClusterCore {
            shards: boards
                .iter()
                .map(|&board| Shard {
                    board,
                    core: SchedCore::new(&Shell::build(board), catalog.clone(), default),
                })
                .collect(),
            placement,
            steal_threshold: DEFAULT_STEAL_THRESHOLD,
            counters: ClusterCounters::default(),
            tenant_weights: BTreeMap::new(),
            merged: VecDeque::new(),
            merged_cap: MERGED_LOG_CAP,
            merged_dropped: 0,
            health: vec![BoardHealth::Healthy; boards.len()],
            reconfig_failures: BTreeMap::new(),
            reconfig_fail_cap: DEFAULT_RECONFIG_FAIL_CAP,
            parked: Vec::new(),
            checkpoint_migration: true,
        }
    }

    /// Set a tenant's QoS weight on every shard (and for routing).
    pub fn set_tenant_weight(&mut self, tenant: usize, weight: u32) {
        self.tenant_weights.insert(tenant, weight.max(1));
        for s in &mut self.shards {
            s.core.set_tenant_weight(tenant, weight);
        }
    }

    /// Enable weighted memory-bandwidth partitioning on every shard
    /// ([`SchedCore::set_bw_partition`]).
    pub fn set_bw_partition(&mut self, on: bool) {
        for s in &mut self.shards {
            s.core.set_bw_partition(on);
        }
    }

    /// Override the work-stealing donor threshold (queued tiles).
    pub fn with_steal_threshold(mut self, tiles: usize) -> ClusterCore {
        self.steal_threshold = tiles;
        self
    }

    /// `false` switches failover to the drop-and-resubmit baseline:
    /// running work on a failed board migrates as *full* requests with
    /// no checkpointed progress (the comparison arm checkpoint-based
    /// migration is measured against).
    pub fn with_checkpoint_migration(mut self, enabled: bool) -> ClusterCore {
        self.checkpoint_migration = enabled;
        self
    }

    /// Override the consecutive-failure cap before a reconfiguration
    /// fault becomes a structured rejection.
    pub fn with_reconfig_fail_cap(mut self, cap: u32) -> ClusterCore {
        self.reconfig_fail_cap = cap.max(1);
        self
    }

    /// Number of boards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn board(&self, b: usize) -> ShellBoard {
        self.shards[b].board
    }

    /// Read-only access to one shard's scheduler core (decision log,
    /// counters, region map, catalog).
    pub fn core(&self, b: usize) -> &SchedCore {
        &self.shards[b].core
    }

    /// Resolve an interned symbol back to its name.  Every shard
    /// derives the same table from the shared catalog, so shard 0's
    /// table answers for the whole cluster.
    pub fn resolve(&self, sym: Sym) -> &str {
        self.shards[0].core.resolve(sym)
    }

    /// Mutable access to one shard's core — for registering custom
    /// per-shard [`super::SchedPolicy`] implementations before traffic
    /// starts.  Mutating queues mid-flight voids decision parity.
    pub fn core_mut(&mut self, b: usize) -> &mut SchedCore {
        &mut self.shards[b].core
    }

    pub fn placement_name(&self) -> &'static str {
        self.placement.name()
    }

    pub fn cluster_counters(&self) -> &ClusterCounters {
        &self.counters
    }

    /// Sum of every shard's [`SchedCounters`] — what aggregate stats
    /// report.
    pub fn total_counters(&self) -> SchedCounters {
        let mut t = SchedCounters::default();
        for s in &self.shards {
            let c = s.core.counters();
            t.reconfigs += c.reconfigs;
            t.reuses += c.reuses;
            t.skips += c.skips;
            t.replications += c.replications;
            t.preemptions += c.preemptions;
            t.resumes += c.resumes;
        }
        t
    }

    /// Route one request to a board and enqueue it there.  Admission
    /// errors (unknown accelerator/variant) surface before routing, so
    /// a rejection never perturbs the placement policy's state.
    /// Returns the board index the request landed on.  Accounted to
    /// tenant `user`; the daemon's admission pipeline routes through
    /// [`ClusterCore::submit_for`].
    pub fn submit(
        &mut self,
        user: usize,
        job: u64,
        accel: &str,
        tiles: usize,
        pin: Option<&str>,
    ) -> Result<usize, String> {
        self.submit_for(user, user, job, accel, tiles, pin)
    }

    /// [`ClusterCore::submit`] with an explicit tenant tag.  Routing
    /// only ever considers `Healthy` boards — the placement policy
    /// routes around `Draining` and `Down` shards by construction.
    pub fn submit_for(
        &mut self,
        user: usize,
        tenant: usize,
        job: u64,
        accel: &str,
        tiles: usize,
        pin: Option<&str>,
    ) -> Result<usize, String> {
        // Validate against shard 0's catalog first (all shards share
        // one catalog): a rejected request must not advance RoundRobin.
        self.shards[0].core.validate(accel, pin)?;
        let healthy = self.healthy_indices();
        if healthy.is_empty() {
            return Err("no healthy boards in the cluster".to_string());
        }
        let accel_sym = self.shards[0]
            .core
            .symbols()
            .lookup(accel)
            .expect("validated accelerator interned");
        let b = self.route_among(&healthy, user, tenant, accel_sym, tiles);
        self.shards[b].core.submit_for(user, tenant, job, accel, tiles, pin)?;
        self.counters.routed += 1;
        Ok(b)
    }

    /// Board indices currently routable (health `Healthy`).
    fn healthy_indices(&self) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&b| self.health[b] == BoardHealth::Healthy)
            .collect()
    }

    /// Ask the placement policy to pick one of `indices` (never empty)
    /// for the request — shared by admission routing and migration
    /// re-routing, so both consult the same policy state.
    fn route_among(
        &mut self,
        indices: &[usize],
        user: usize,
        tenant: usize,
        accel: Sym,
        tiles: usize,
    ) -> usize {
        let ClusterCore { shards, placement, tenant_weights, .. } = self;
        let views: Vec<ShardView<'_>> = indices
            .iter()
            .map(|&i| {
                let s = &shards[i];
                ShardView {
                    board: s.board,
                    regions: s.core.regions(),
                    backlog_tiles: s.core.backlog_tiles(),
                    pending: s.core.pending(),
                    running: s.core.running_count(),
                }
            })
            .collect();
        let weight = tenant_weights.get(&tenant).copied().unwrap_or(1);
        let req = RouteReq { user, tenant, weight, accel, tiles };
        indices[placement.route(&views, &req).min(indices.len() - 1)]
    }

    /// Per-tenant scheduling counters summed across every shard.
    pub fn tenant_counters(&self) -> BTreeMap<usize, TenantSchedCounters> {
        let mut out: BTreeMap<usize, TenantSchedCounters> = BTreeMap::new();
        for s in &self.shards {
            for (&tenant, c) in s.core.tenant_counters() {
                let t = out.entry(tenant).or_default();
                t.admitted += c.admitted;
                t.completed += c.completed;
                t.preempted += c.preempted;
                t.rejected += c.rejected;
            }
        }
        out
    }

    /// Work-stealing hook — call right before board `b`'s scheduling
    /// round.  A fully idle shard pulls one request from the deepest
    /// *stealable* backlog above the threshold (checkpoint-pinned
    /// remainders don't count — they can never move); `true` when a
    /// request moved.
    pub fn steal_into(&mut self, b: usize) -> bool {
        if self.shards.len() < 2 || self.health[b] != BoardHealth::Healthy {
            return false;
        }
        if self.shards[b].core.has_pending() || self.shards[b].core.running_count() > 0 {
            return false;
        }
        // Down boards hold no queue; Draining boards are valid donors
        // (stealing accelerates their drain).
        let donor = (0..self.shards.len())
            .filter(|&i| i != b && self.health[i] != BoardHealth::Down)
            .map(|i| (self.shards[i].core.stealable_tiles(), i))
            .filter(|&(tiles, _)| tiles > self.steal_threshold)
            .max_by_key(|&(tiles, i)| (tiles, std::cmp::Reverse(i)))
            .map(|(_, i)| i);
        let Some(donor) = donor else { return false };
        let Some(req) = self.shards[donor].core.steal_back() else { return false };
        self.shards[b].core.inject(req);
        self.counters.steals += 1;
        true
    }

    // ---- per-shard delegation (the harness round lifecycle) ---------

    pub fn begin_round_at(&mut self, b: usize, now: u64) {
        self.shards[b].core.begin_round_at(now);
    }

    /// Next placement on board `b`; also appended to the merged log.
    /// A `Down` board never schedules (its queues were drained at
    /// failover; this guard keeps a stale harness loop harmless).
    pub fn next_decision(&mut self, b: usize) -> Option<Decision> {
        if self.health[b] == BoardHealth::Down {
            return None;
        }
        let d = self.shards[b].core.next_decision()?;
        self.push_merged(b, d);
        Some(d)
    }

    /// Append to the ring-capped merged `(board, decision)` log.
    fn push_merged(&mut self, b: usize, d: Decision) {
        if self.merged.len() >= self.merged_cap {
            self.merged.pop_front();
            self.merged_dropped += 1;
        }
        self.merged.push_back((b, d));
    }

    /// Override the merged-log ring cap (default 65 536) — ops tuning
    /// and wrap-boundary tests.
    pub fn set_merged_log_cap(&mut self, cap: usize) {
        self.merged_cap = cap.max(1);
        while self.merged.len() > self.merged_cap {
            self.merged.pop_front();
            self.merged_dropped += 1;
        }
    }

    // ---- failure domain: health lifecycle, migration, retries -------

    pub fn health(&self, b: usize) -> BoardHealth {
        self.health[b]
    }

    /// Boards currently routable.
    pub fn healthy_count(&self) -> usize {
        self.health.iter().filter(|&&h| h == BoardHealth::Healthy).count()
    }

    /// Operator drain: no new work routes to board `b`; queued and
    /// running work finishes in place.  No-op on a `Down` board.
    pub fn drain_board(&mut self, b: usize) {
        if self.health[b] == BoardHealth::Healthy {
            self.health[b] = BoardHealth::Draining;
        }
    }

    /// Bring board `b` back into rotation (from `Draining` or `Down`).
    /// A revived board comes back blank — failover cleared its
    /// residency — so the first placements reconfigure from scratch.
    pub fn revive_board(&mut self, b: usize) {
        self.health[b] = BoardHealth::Healthy;
    }

    /// Board `b` failed at virtual time `now`: checkpoint every running
    /// dispatch (progress preserved; `Preempt` decisions are logged so
    /// migrations show up in the decision sequence), drain the queued
    /// requests, and re-inject everything into healthy shards via the
    /// placement policy — progress records are adopted by the target
    /// shard under fresh checkpoint ids.  With no healthy board left,
    /// the work parks until [`ClusterCore::release_retries`] finds a
    /// revived shard.  Tenant counters stay conserved: migration uses
    /// [`SchedCore::inject`] (no re-admission), so every request is
    /// admitted once and completed once, whichever board finishes it.
    pub fn mark_board_down(&mut self, b: usize, now: u64) -> FailoverReport {
        let mut report = FailoverReport::default();
        if self.health[b] == BoardHealth::Down {
            return report;
        }
        self.health[b] = BoardHealth::Down;
        self.counters.failovers += 1;
        // 1. Running dispatches: checkpoint + migrate the remainders.
        let keep = self.checkpoint_migration;
        let drains = self.shards[b].core.drain_running_for_failover(now, keep);
        for f in drains {
            self.counters.lost_ns += f.lost_ns;
            let job = f.request.job;
            self.push_merged(b, f.decision);
            let (to, new_ckpt) = self.migrate(b, f.request, f.checkpoint, None, now, &mut report);
            report.drained.push(DrainedRun { anchor: f.anchor, job, done: f.done, to, new_ckpt });
        }
        // 2. Queued requests — including not-yet-resumed remainders,
        //    whose progress records move along with them (the failover
        //    drain, unlike `drain_pending`, keeps each checkpoint
        //    paired with its request).
        for (mut req, ck) in self.shards[b].core.drain_pending_with_checkpoints() {
            match (req.resume.take(), ck) {
                (Some(old), Some(c)) => {
                    self.migrate(b, req, Some(c), Some((b, old)), now, &mut report);
                }
                _ => {
                    self.migrate(b, req, None, None, now, &mut report);
                }
            }
        }
        // 3. Retries parked against this board lose their shard: pull
        //    their progress records along for a later adoption.  The
        //    hardware snapshot stays in the dead board's store under
        //    the old id — `snap_home` tells the release-time MovedCkpt
        //    where to find it.
        let ClusterCore { parked, shards, .. } = self;
        for p in parked.iter_mut().filter(|p| p.origin == b) {
            if let Some(old) = p.req.resume.take() {
                p.ckpt = shards[b].core.take_checkpoint(old);
                p.snap_home = Some((b, old));
            }
        }
        // 4. The board comes back blank: forget its residency so a
        //    post-revival reuse can never trust pre-failure modules.
        self.shards[b].core.clear_residency();
        report
    }

    /// Route one drained request into a healthy shard (adopting its
    /// progress record there under a fresh id), or park it for the
    /// next revival when no board is healthy.  `snapshot_from` names
    /// the old `(board, id)` snapshot home for the daemon's mirror.
    fn migrate(
        &mut self,
        origin: usize,
        mut req: Request,
        ckpt: Option<Checkpoint>,
        snapshot_from: Option<(usize, u64)>,
        now: u64,
        report: &mut FailoverReport,
    ) -> (Option<usize>, Option<u64>) {
        let healthy = self.healthy_indices();
        if healthy.is_empty() {
            // Remember where the (possible) hardware snapshot lives so
            // the release can tell the daemon to move it.
            self.parked.push(Parked { at_ns: now, origin, req, ckpt, snap_home: snapshot_from });
            return (None, None);
        }
        let to = self.route_among(&healthy, req.user, req.tenant, req.accel, req.tiles);
        let new_ckpt = ckpt.map(|c| self.shards[to].core.adopt_checkpoint(c));
        if let Some(id) = new_ckpt {
            req.resume = Some(id);
            if let Some(from) = snapshot_from {
                report.moved_ckpts.push(MovedCkpt {
                    job: req.job,
                    from: Some(from),
                    to,
                    new_ckpt: id,
                });
            }
        }
        report.migrated_jobs.push((req.job, to));
        self.counters.migrations += 1;
        self.shards[to].core.inject(req);
        (Some(to), new_ckpt)
    }

    /// Report the outcome of a `reconfigure` decision's hardware
    /// mirror.  Call for EVERY reconfiguring dispatch, success or
    /// failure, at the same round-lifecycle point in both harnesses —
    /// the per-accelerator failure streak (and therefore the backoff
    /// and cap) is part of the parity contract.
    ///
    /// Success (`failed == false`) resets the accelerator's streak and
    /// returns `None`.  Failure rolls the placement back
    /// ([`SchedCore::rollback_failed_dispatch`]) and either parks the
    /// request for an exponential-backoff retry or, past
    /// `reconfig_fail_cap` consecutive failures, surfaces it as a
    /// structured rejection through the shard's `take_rejected` buffer.
    pub fn reconfig_outcome(
        &mut self,
        b: usize,
        d: &Decision,
        failed: bool,
        now: u64,
    ) -> Option<FailDisposition> {
        if !failed {
            self.reconfig_failures.remove(&d.accel);
            return None;
        }
        let req = self.shards[b].core.rollback_failed_dispatch(d);
        let streak = {
            let e = self.reconfig_failures.entry(d.accel).or_insert(0);
            *e += 1;
            *e
        };
        self.counters.reconfig_failures += 1;
        if streak > self.reconfig_fail_cap {
            self.reconfig_failures.remove(&d.accel);
            self.counters.reconfig_rejections += 1;
            let accel_name = self.shards[b].core.resolve(d.accel).to_string();
            self.shards[b].core.push_rejected(
                req,
                format!(
                    "partial reconfiguration of {accel_name:?} failed {streak} consecutive \
                     times (cap {}); giving up",
                    self.reconfig_fail_cap
                ),
            );
            Some(FailDisposition::Rejected)
        } else {
            let at_ns = now + (RETRY_BACKOFF_BASE_NS << (streak - 1).min(16));
            self.counters.reconfig_retries += 1;
            // A retried Resume's checkpoint and snapshot both stay on
            // the origin shard under the original id; snap_home is only
            // needed if the origin later fails (mark_board_down fills
            // it when pulling the checkpoint out).
            self.parked.push(Parked { at_ns, origin: b, req, ckpt: None, snap_home: None });
            Some(FailDisposition::Retry { at_ns })
        }
    }

    /// A dispatch's execution failed transiently at its completion
    /// point: the work is lost and the whole dispatch re-queued at the
    /// front of its owner's queue on the same shard
    /// ([`SchedCore::fail_running`]).  `false` when nothing was running
    /// at `anchor`.
    pub fn fail_run(&mut self, b: usize, anchor: usize, now: u64) -> bool {
        match self.shards[b].core.fail_running(anchor, now) {
            Some(lost) => {
                self.counters.run_faults += 1;
                self.counters.lost_ns += lost;
                true
            }
            None => false,
        }
    }

    /// Earliest parked retry deadline, if any — harnesses that lost
    /// their wake-up event can re-arm from this.
    pub fn next_retry_at(&self) -> Option<u64> {
        self.parked.iter().map(|p| p.at_ns).min()
    }

    /// Requests currently parked (backoff retries + revival waits).
    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }

    /// Re-inject every parked request whose deadline has passed: plain
    /// retries go back to their origin shard when it still lives
    /// (their checkpoints, if any, are still stored there), everything
    /// else re-routes over the healthy boards — adopting carried
    /// progress records under fresh ids on the target shard.  Entries
    /// that still have no live home stay parked.  Call once per event
    /// batch, before ingest, in BOTH harnesses (parity).
    pub fn release_retries(&mut self, now: u64) -> RetryOutcome {
        let mut out = RetryOutcome::default();
        if self.parked.is_empty() {
            return out;
        }
        let parked = std::mem::take(&mut self.parked);
        for p in parked {
            if p.at_ns > now {
                self.parked.push(p);
                continue;
            }
            // A retry whose checkpoint still lives on its (alive)
            // origin shard must go back there.
            if p.ckpt.is_none() && p.req.resume.is_some() {
                if self.health[p.origin] != BoardHealth::Down {
                    self.shards[p.origin].core.inject(p.req);
                    out.released += 1;
                } else {
                    // Defensive: mark_board_down pulls checkpoints out
                    // of failing shards, so this arm is unreachable.
                    self.parked.push(p);
                }
                continue;
            }
            let healthy = self.healthy_indices();
            if healthy.is_empty() {
                self.parked.push(p);
                continue;
            }
            let Parked { mut req, ckpt, snap_home, .. } = p;
            let to = self.route_among(&healthy, req.user, req.tenant, req.accel, req.tiles);
            if let Some(c) = ckpt {
                let id = self.shards[to].core.adopt_checkpoint(c);
                out.moved_ckpts.push(MovedCkpt {
                    job: req.job,
                    from: snap_home,
                    to,
                    new_ckpt: id,
                });
                req.resume = Some(id);
                self.counters.migrations += 1;
            }
            self.shards[to].core.inject(req);
            out.released += 1;
        }
        out
    }

    pub fn complete(&mut self, b: usize, anchor: usize) {
        self.shards[b].core.complete(anchor);
    }

    pub fn evict(&mut self, b: usize, anchor: usize) {
        self.shards[b].core.evict(anchor);
    }

    pub fn mark_running(&mut self, b: usize, d: &Decision, start: u64, end: u64) {
        self.shards[b].core.mark_running(d, start, end);
    }

    pub fn service_ns(&self, b: usize, d: &Decision, concurrent: usize) -> u64 {
        self.shards[b].core.service_ns(d, concurrent)
    }

    pub fn busy_anchors(&self, b: usize) -> usize {
        self.shards[b].core.busy_anchors()
    }

    pub fn take_rejected(&mut self, b: usize) -> Vec<(Request, String)> {
        self.shards[b].core.take_rejected()
    }

    pub fn preempt_tick_due(
        &self,
        b: usize,
        next_tick: &mut Option<u64>,
        now: u64,
    ) -> Option<u64> {
        self.shards[b].core.preempt_tick_due(next_tick, now)
    }

    // ---- cluster-wide queries and tenant lifecycle ------------------

    /// Requests queued across every shard.
    pub fn pending(&self) -> usize {
        self.shards.iter().map(|s| s.core.pending()).sum()
    }

    pub fn has_pending(&self) -> bool {
        self.shards.iter().any(|s| s.core.has_pending())
    }

    /// In-flight dispatches across every shard.
    pub fn running_total(&self) -> usize {
        self.shards.iter().map(|s| s.core.running_count()).sum()
    }

    /// Route `user` to the scheduling policy named `name` on every
    /// shard; `false` if the name is unknown (all shards share the
    /// built-in registry, so the answer is uniform).
    pub fn set_user_policy(&mut self, user: usize, name: &str) -> bool {
        let mut ok = true;
        for s in &mut self.shards {
            ok &= s.core.set_user_policy(user, name);
        }
        ok
    }

    pub fn policy_name_of(&self, user: usize) -> &'static str {
        self.shards[0].core.policy_name_of(user)
    }

    /// Retire `user` on every shard; returns the dropped queued
    /// requests tagged with the shard they were queued on (the daemon
    /// fails their replies and drops per-board snapshots).
    pub fn retire_user(&mut self, user: usize) -> Vec<(usize, Request)> {
        let mut out = Vec::new();
        for (b, s) in self.shards.iter_mut().enumerate() {
            out.extend(s.core.retire_user(user).into_iter().map(|r| (b, r)));
        }
        // The departed user's parked retries must never re-inject: a
        // later release would dispatch a job token nobody owns.  A
        // parked Resume's checkpoint still lives in its origin shard's
        // store — drop it (the invariant: a resume-request leaving by
        // any path other than a Resume dispatch drops its checkpoint);
        // the harness drops the matching snapshot via the returned
        // request's `resume` id.
        let parked = std::mem::take(&mut self.parked);
        for p in parked {
            if p.req.user == user {
                let mut req = p.req;
                let mut b = p.origin.min(self.shards.len() - 1);
                if let Some((home, old)) = p.snap_home {
                    // The carried checkpoint drops with the entry; the
                    // hardware snapshot still sits on its home board —
                    // re-point `resume` so the harness's usual cleanup
                    // (`snapshots.remove(resume id)`) finds it.
                    req.resume = Some(old);
                    b = home.min(self.shards.len() - 1);
                } else if let Some(id) = req.resume {
                    let _ = self.shards[b].core.take_checkpoint(id);
                }
                out.push((b, req));
            } else {
                self.parked.push(p);
            }
        }
        out
    }

    /// Drain every queued request on every shard (stall guard).
    pub fn drain_pending(&mut self) -> Vec<(usize, Request)> {
        let mut out = Vec::new();
        for (b, s) in self.shards.iter_mut().enumerate() {
            out.extend(s.core.drain_pending().into_iter().map(|r| (b, r)));
        }
        out
    }

    /// The merged `(board, decision)` log in global dispatch order.
    pub fn merged_log(&self) -> impl Iterator<Item = &(usize, Decision)> {
        self.merged.iter()
    }

    /// The last `n` merged entries — O(1) positioning.
    pub fn merged_log_tail(&self, n: usize) -> impl Iterator<Item = &(usize, Decision)> {
        self.merged.iter().skip(self.merged.len().saturating_sub(n))
    }

    pub fn merged_dropped(&self) -> u64 {
        self.merged_dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::DecisionKind;

    fn catalog() -> Catalog {
        Catalog::load_default().unwrap()
    }

    fn cluster(n: usize, kind: PlacementKind) -> ClusterCore {
        let boards: Vec<ShellBoard> = (0..n)
            .map(|i| if i % 2 == 0 { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 })
            .collect();
        ClusterCore::new(&boards, &catalog(), Policy::Elastic, kind)
    }

    /// Drive one shard's round to completion, replaying completions
    /// immediately (run-to-completion harness stand-in).
    fn drain_board(c: &mut ClusterCore, b: usize, now: u64) -> Vec<Decision> {
        c.begin_round_at(b, now);
        let mut out = Vec::new();
        while let Some(d) = c.next_decision(b) {
            assert_ne!(d.kind, DecisionKind::Preempt);
            let lat = c.service_ns(b, &d, c.busy_anchors(b).saturating_sub(1));
            c.mark_running(b, &d, now, now + lat.max(1));
            out.push(d);
        }
        for d in &out {
            c.complete(b, d.anchor);
        }
        out
    }

    #[test]
    fn round_robin_rotates_boards() {
        let mut c = cluster(3, PlacementKind::RoundRobin);
        let mut routed = Vec::new();
        for j in 0..6 {
            routed.push(c.submit(0, j, "vadd", 1, None).unwrap());
        }
        assert_eq!(routed, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(c.cluster_counters().routed, 6);
    }

    #[test]
    fn least_loaded_prefers_empty_board() {
        let mut c = cluster(2, PlacementKind::LeastLoaded);
        let b0 = c.submit(0, 0, "mandelbrot", 10, None).unwrap();
        assert_eq!(b0, 0, "tie breaks to the lowest index");
        let b1 = c.submit(1, 1, "sobel", 1, None).unwrap();
        assert_eq!(b1, 1, "board 0 carries 10 queued tiles");
    }

    #[test]
    fn locality_prefers_resident_board() {
        let mut c = cluster(2, PlacementKind::Locality);
        // Nothing resident yet: least-loaded → board 0; run it so the
        // sobel module becomes resident there.
        assert_eq!(c.submit(0, 0, "sobel", 1, Some("sobel_v1")).unwrap(), 0);
        drain_board(&mut c, 0, 0);
        // Queue more sobel on the resident board, making it the
        // *heavier* board; an unrelated accelerator routes least-loaded
        // to the empty board 1.
        assert_eq!(c.submit(0, 1, "sobel", 8, Some("sobel_v1")).unwrap(), 0);
        assert_eq!(c.submit(1, 2, "mandelbrot", 1, None).unwrap(), 1);
        // Locality: sobel keeps routing to its resident board even
        // though board 1 now carries less queued work than board 0.
        assert_eq!(c.submit(0, 3, "sobel", 1, Some("sobel_v1")).unwrap(), 0);
        // And the resident instance is reused, not reconfigured.
        c.begin_round_at(0, 1);
        let d = c.next_decision(0).unwrap();
        assert!(!d.reconfigure, "resident instance must be reused: {d:?}");
    }

    #[test]
    fn locality_spills_past_backlog_limit() {
        let mut c = cluster(2, PlacementKind::Locality);
        assert_eq!(c.submit(0, 0, "sobel", 1, Some("sobel_v1")).unwrap(), 0);
        drain_board(&mut c, 0, 0);
        // Saturate the resident board past the default limit: the next
        // sobel request spills to the least-loaded board instead.
        assert_eq!(
            c.submit(0, 1, "sobel", DEFAULT_STEAL_THRESHOLD + 1, Some("sobel_v1")).unwrap(),
            0
        );
        assert_eq!(c.submit(0, 2, "sobel", 1, Some("sobel_v1")).unwrap(), 1);
    }

    #[test]
    fn idle_board_steals_from_deep_backlog() {
        let mut c = cluster(2, PlacementKind::LeastLoaded).with_steal_threshold(8);
        // Board 0: deep backlog; board 1: idle.
        for j in 0..4 {
            c.shards[0].core.submit(0, j, "vadd", 8, None).unwrap();
        }
        assert!(c.steal_into(1), "idle board must steal");
        assert_eq!(c.cluster_counters().steals, 1);
        assert_eq!(c.core(1).pending(), 1);
        assert_eq!(c.core(0).pending(), 3);
        // A busy board never steals.
        assert!(!c.steal_into(0));
        // Below the threshold, nothing moves.
        let mut c2 = cluster(2, PlacementKind::LeastLoaded).with_steal_threshold(1000);
        c2.shards[0].core.submit(0, 0, "vadd", 8, None).unwrap();
        assert!(!c2.steal_into(1));
    }

    #[test]
    fn rejection_does_not_advance_round_robin() {
        let mut c = cluster(2, PlacementKind::RoundRobin);
        assert!(c.submit(0, 0, "flux_capacitor", 1, None).is_err());
        assert!(c.submit(0, 1, "vadd", 1, Some("vadd_v9")).is_err());
        assert_eq!(c.cluster_counters().routed, 0);
        // First accepted request still lands on board 0.
        assert_eq!(c.submit(0, 2, "vadd", 1, None).unwrap(), 0);
    }

    #[test]
    fn merged_log_tags_boards() {
        let mut c = cluster(2, PlacementKind::RoundRobin);
        c.submit(0, 0, "vadd", 1, None).unwrap();
        c.submit(1, 1, "dct", 1, None).unwrap();
        drain_board(&mut c, 0, 0);
        drain_board(&mut c, 1, 0);
        let merged: Vec<(usize, String)> = c
            .merged_log()
            .map(|(b, d)| (*b, c.resolve(d.accel).to_string()))
            .collect();
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0], (0, "vadd".to_string()));
        assert_eq!(merged[1], (1, "dct".to_string()));
        // Per-shard logs partition the merged log.
        assert_eq!(c.core(0).decision_log().count(), 1);
        assert_eq!(c.core(1).decision_log().count(), 1);
        // Tail query returns only the newest entries.
        assert_eq!(c.merged_log_tail(1).count(), 1);
        assert_eq!(c.merged_log_tail(1).next().unwrap().0, 1);
    }

    #[test]
    fn merged_log_ring_wrap_boundary() {
        let mut c = cluster(2, PlacementKind::RoundRobin);
        c.set_merged_log_cap(3);
        for j in 0..3 {
            let b = c.submit(0, j, "vadd", 1, None).unwrap();
            drain_board(&mut c, b, j);
        }
        assert_eq!(c.merged_log().count(), 3, "at the cap: nothing dropped");
        assert_eq!(c.merged_dropped(), 0);
        for j in 3..5 {
            let b = c.submit(0, j, "vadd", 1, None).unwrap();
            drain_board(&mut c, b, j);
        }
        let jobs: Vec<u64> = c.merged_log().map(|(_, d)| d.job).collect();
        assert_eq!(jobs, vec![2, 3, 4], "oldest dropped first across the wrap");
        assert_eq!(c.merged_dropped(), 2);
        // Tail positioning at the boundary.
        assert_eq!(c.merged_log_tail(3).count(), 3);
        assert_eq!(c.merged_log_tail(9).count(), 3, "over-long tail = whole ring");
        assert_eq!(c.merged_log_tail(1).next().unwrap().1.job, 4);
        assert_eq!(c.merged_log_tail(0).count(), 0);
        // Shrinking below the live length drops the oldest.
        c.set_merged_log_cap(1);
        assert_eq!(c.merged_log().count(), 1);
        assert_eq!(c.merged_log().next().unwrap().1.job, 4);
        assert_eq!(c.merged_dropped(), 4);
    }

    #[test]
    fn health_lifecycle_routes_around_drained_and_down_boards() {
        let mut c = cluster(3, PlacementKind::RoundRobin);
        assert_eq!(c.healthy_count(), 3);
        // Draining board 1: round-robin now rotates over {0, 2} only.
        c.drain_board(1);
        assert_eq!(c.health(1), BoardHealth::Draining);
        let routed: Vec<usize> =
            (0..4).map(|j| c.submit(0, j, "vadd", 1, None).unwrap()).collect();
        assert_eq!(routed, vec![0, 2, 0, 2], "no new work on a draining board");
        // Down takes board 0 out too; everything lands on board 2.
        c.mark_board_down(0, 0);
        assert_eq!(c.health(0), BoardHealth::Down);
        assert_eq!(c.healthy_count(), 1);
        assert_eq!(c.submit(0, 9, "vadd", 1, None).unwrap(), 2);
        // Revival rejoins the rotation.
        c.revive_board(0);
        c.revive_board(1);
        assert_eq!(c.healthy_count(), 3);
        // Submitting with every board down is a structured error.
        c.mark_board_down(0, 0);
        c.mark_board_down(1, 0);
        c.mark_board_down(2, 0);
        assert!(c.submit(0, 10, "vadd", 1, None).is_err());
        // A down board never schedules or steals.
        assert!(c.next_decision(0).is_none());
        assert!(!c.steal_into(0));
    }

    #[test]
    fn board_down_migrates_queued_and_running_work_with_progress() {
        let mut c = cluster(2, PlacementKind::LeastLoaded);
        // Board 0: one long running dispatch + one queued request.
        assert_eq!(c.submit(0, 0, "mandelbrot", 100, Some("mandelbrot_v1")).unwrap(), 0);
        c.begin_round_at(0, 0);
        let d = c.next_decision(0).unwrap();
        let lat = c.service_ns(0, &d, 0);
        c.mark_running(0, &d, 0, lat);
        c.shards[0].core.submit(0, 1, "sobel", 2, Some("sobel_v1")).unwrap();
        let before = c.tenant_counters()[&0].admitted;

        let report = c.mark_board_down(0, lat / 2);
        // Both requests migrated to board 1 — the running one carries a
        // checkpoint adopted by the target shard.
        assert_eq!(report.migrated_jobs.len(), 2);
        assert!(report.migrated_jobs.iter().all(|&(_, to)| to == 1));
        assert_eq!(report.drained.len(), 1);
        let dr = report.drained[0];
        assert!(dr.done > 0, "mid-run progress must be preserved: {dr:?}");
        assert_eq!((dr.to, dr.job), (Some(1), 0));
        let new_id = dr.new_ckpt.unwrap();
        assert!(c.core(1).checkpoint(new_id).is_some(), "target adopted the checkpoint");
        assert_eq!(c.cluster_counters().failovers, 1);
        assert_eq!(c.cluster_counters().migrations, 2);
        assert!(c.cluster_counters().lost_ns > 0);
        // The migration shows up in the merged log as a Preempt.
        assert!(c
            .merged_log()
            .any(|(b, d)| *b == 0 && d.kind == DecisionKind::Preempt && d.job == 0));
        // Tenant counters conserved: no re-admission on migration.
        assert_eq!(c.tenant_counters()[&0].admitted, before);
        // Board 1 resumes the remainder with the adopted checkpoint and
        // runs the queued request — nothing lost, nothing doubled.
        c.begin_round_at(1, lat / 2);
        let mut kinds = Vec::new();
        while let Some(d1) = c.next_decision(1) {
            if d1.kind == DecisionKind::Resume {
                assert_eq!(d1.ckpt, Some(new_id));
                assert_eq!(d1.tiles as u64 + dr.done as u64, 100);
            }
            let l = c.service_ns(1, &d1, 0);
            c.mark_running(1, &d1, lat / 2, lat / 2 + l);
            kinds.push(d1.kind);
        }
        assert!(kinds.contains(&DecisionKind::Resume), "{kinds:?}");
        assert!(kinds.contains(&DecisionKind::Run), "{kinds:?}");
        assert!(c.core(1).checkpoint(new_id).is_none(), "checkpoint consumed at resume");
    }

    #[test]
    fn reconfig_failures_back_off_then_reject_at_cap() {
        let mut c = cluster(1, PlacementKind::RoundRobin).with_reconfig_fail_cap(2);
        c.submit(0, 7, "sobel", 2, Some("sobel_v1")).unwrap();
        let mut now = 0u64;
        let mut retry_times = Vec::new();
        for attempt in 0..2 {
            c.begin_round_at(0, now);
            let d = c.next_decision(0).unwrap();
            assert!(d.reconfigure);
            match c.reconfig_outcome(0, &d, true, now) {
                Some(FailDisposition::Retry { at_ns }) => {
                    assert!(at_ns > now, "backoff must be in the future");
                    retry_times.push(at_ns - now);
                    now = at_ns;
                    let rel = c.release_retries(now);
                    assert_eq!(rel.released, 1, "attempt {attempt} must re-queue");
                }
                other => panic!("expected a retry, got {other:?}"),
            }
        }
        assert!(retry_times[1] > retry_times[0], "backoff must grow: {retry_times:?}");
        // Third consecutive failure exceeds the cap: structured reject.
        c.begin_round_at(0, now);
        let d = c.next_decision(0).unwrap();
        assert_eq!(c.reconfig_outcome(0, &d, true, now), Some(FailDisposition::Rejected));
        let rejected = c.take_rejected(0);
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].0.job, 7);
        assert!(rejected[0].1.contains("failed 3 consecutive times"), "{}", rejected[0].1);
        assert_eq!(c.cluster_counters().reconfig_failures, 3);
        assert_eq!(c.cluster_counters().reconfig_retries, 2);
        assert_eq!(c.cluster_counters().reconfig_rejections, 1);
        // A later success resets the streak.
        c.submit(0, 8, "sobel", 2, Some("sobel_v1")).unwrap();
        c.begin_round_at(0, now + 1);
        let d = c.next_decision(0).unwrap();
        assert!(c.reconfig_outcome(0, &d, false, now + 1).is_none());
    }

    #[test]
    fn retry_parked_on_down_board_rehomes_at_release() {
        let mut c = cluster(2, PlacementKind::RoundRobin);
        assert_eq!(c.submit(0, 0, "sobel", 2, Some("sobel_v1")).unwrap(), 0);
        c.begin_round_at(0, 0);
        let d = c.next_decision(0).unwrap();
        let Some(FailDisposition::Retry { at_ns }) = c.reconfig_outcome(0, &d, true, 0) else {
            panic!("expected retry");
        };
        // The origin board dies before the backoff expires: the retry
        // re-routes to the surviving board.
        c.mark_board_down(0, 1);
        let rel = c.release_retries(at_ns);
        assert_eq!(rel.released, 1);
        assert_eq!(c.core(1).pending(), 1, "retry re-homed on the healthy board");
        assert_eq!(c.core(0).pending(), 0);
    }

    #[test]
    fn board_down_carries_queued_remainder_checkpoints() {
        // A preempted remainder sitting in a failed board's QUEUE (not
        // running) must migrate together with its checkpoint: the
        // normal drain_pending drops departing checkpoints, so the
        // failover drain pairs them explicitly.
        let boards = [ShellBoard::Ultra96, ShellBoard::Zcu102];
        let mut c =
            ClusterCore::new(&boards, &catalog(), Policy::Quantum, PlacementKind::LeastLoaded);
        // Three long streams fill board 0's fabric (the shard core's
        // quantum-preemption scenario from core.rs).
        for j in 0..3 {
            c.shards[0].core.submit(0, j, "mandelbrot", 100, Some("mandelbrot_v1")).unwrap();
        }
        c.begin_round_at(0, 0);
        while let Some(d) = c.next_decision(0) {
            let lat = c.service_ns(0, &d, c.busy_anchors(0).saturating_sub(1));
            c.mark_running(0, &d, 0, lat);
        }
        // A starved tenant past the quantum checkpoints one stream; the
        // remainder re-queues (pinned, resume id) but cannot place —
        // the fabric refills the same round.
        c.shards[0].core.submit(1, 10, "sobel", 2, Some("sobel_v1")).unwrap();
        c.begin_round_at(0, 50_000_000);
        let p = c.next_decision(0).unwrap();
        assert_eq!(p.kind, DecisionKind::Preempt);
        let old_id = p.ckpt.unwrap();
        while let Some(d) = c.next_decision(0) {
            let lat = c.service_ns(0, &d, c.busy_anchors(0).saturating_sub(1));
            c.mark_running(0, &d, 50_000_000, 50_000_000 + lat);
        }
        assert!(c.core(0).checkpoint(old_id).is_some(), "remainder queued with its ckpt");
        assert!(c.core(0).has_pending());

        let report = c.mark_board_down(0, 60_000_000);
        // The queued remainder's checkpoint travelled: a MovedCkpt
        // names the dead board's store as the snapshot home and the
        // adopting shard holds the progress record.
        let mv = report
            .moved_ckpts
            .iter()
            .find(|m| m.from == Some((0, old_id)))
            .expect("queued remainder's checkpoint must migrate with it");
        assert_eq!(mv.to, 1);
        assert!(c.core(1).checkpoint(mv.new_ckpt).is_some());
        assert!(c.core(0).checkpoint(old_id).is_none(), "no orphan on the dead shard");
        // And the remainder re-dispatches as a Resume consuming the
        // adopted id — progress preserved, not restarted.
        c.begin_round_at(1, 60_000_000);
        let mut resumed = false;
        while let Some(d) = c.next_decision(1) {
            if d.ckpt == Some(mv.new_ckpt) {
                assert_eq!(d.kind, DecisionKind::Resume);
                resumed = true;
            }
            let lat = c.service_ns(1, &d, c.busy_anchors(1).saturating_sub(1));
            c.mark_running(1, &d, 60_000_000, 60_000_000 + lat);
        }
        assert!(resumed, "migrated remainder must resume on the survivor");
    }

    #[test]
    fn parked_resume_retry_rehomes_with_snapshot_pointer() {
        // The full unlucky chain: failover migrates a checkpointed
        // remainder to board B; B's Resume hits a reconfiguration
        // fault and parks; B dies before the backoff expires.  The
        // release must adopt the progress record on a survivor AND
        // tell the harness exactly where the old hardware snapshot
        // lives (MovedCkpt::from), or the daemon's restore would look
        // in the wrong store.
        let mut c = cluster(3, PlacementKind::LeastLoaded);
        assert_eq!(c.submit(0, 0, "mandelbrot", 100, Some("mandelbrot_v1")).unwrap(), 0);
        c.begin_round_at(0, 0);
        let d = c.next_decision(0).unwrap();
        let lat = c.service_ns(0, &d, 0);
        c.mark_running(0, &d, 0, lat);
        let report = c.mark_board_down(0, lat / 2);
        let dr = report.drained[0];
        let (to, id) = (dr.to.unwrap(), dr.new_ckpt.unwrap());
        c.begin_round_at(to, lat / 2);
        let r = c.next_decision(to).unwrap();
        assert_eq!(r.kind, DecisionKind::Resume);
        assert!(r.reconfigure, "fresh shard must reload");
        let Some(FailDisposition::Retry { at_ns }) = c.reconfig_outcome(to, &r, true, lat / 2)
        else {
            panic!("expected a retry");
        };
        assert!(c.core(to).checkpoint(id).is_some(), "rollback re-stores the checkpoint");
        c.mark_board_down(to, lat / 2 + 1);
        let rel = c.release_retries(at_ns);
        assert_eq!(rel.released, 1);
        assert_eq!(rel.moved_ckpts.len(), 1);
        let mv = rel.moved_ckpts[0];
        assert_eq!(mv.from, Some((to, id)), "snapshot home must be reported");
        let survivor = (0..3).find(|&x| x != 0 && x != to).unwrap();
        assert_eq!(mv.to, survivor);
        assert!(c.core(survivor).checkpoint(mv.new_ckpt).is_some());
        assert_eq!(c.core(survivor).pending(), 1, "remainder re-homed on the survivor");
    }

    #[test]
    fn retire_user_drops_parked_retries() {
        let mut c = cluster(1, PlacementKind::RoundRobin);
        c.submit(0, 5, "sobel", 1, Some("sobel_v1")).unwrap();
        c.begin_round_at(0, 0);
        let d = c.next_decision(0).unwrap();
        assert!(matches!(
            c.reconfig_outcome(0, &d, true, 0),
            Some(FailDisposition::Retry { .. })
        ));
        assert_eq!(c.parked_count(), 1);
        let dropped = c.retire_user(0);
        assert_eq!(dropped.len(), 1, "parked retry returned to the harness");
        assert_eq!(dropped[0].1.job, 5);
        assert_eq!(c.parked_count(), 0);
        // Nothing re-injects later.
        assert_eq!(c.release_retries(u64::MAX / 2).released, 0);
        assert!(!c.has_pending());
    }

    #[test]
    fn retire_and_drain_tag_boards() {
        let mut c = cluster(2, PlacementKind::RoundRobin);
        c.submit(0, 0, "vadd", 1, None).unwrap(); // board 0
        c.submit(0, 1, "vadd", 1, None).unwrap(); // board 1
        let retired = c.retire_user(0);
        let boards: Vec<usize> = retired.iter().map(|(b, _)| *b).collect();
        assert_eq!(boards, vec![0, 1]);
        assert!(!c.has_pending());
        c.submit(1, 2, "dct", 1, None).unwrap();
        assert_eq!(c.drain_pending().len(), 1);
    }
}
