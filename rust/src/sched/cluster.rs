//! # The cluster layer — N boards, one scheduler (§5's heterogeneous
//! evaluation scaled out)
//!
//! FOS evaluates per-board (Ultra96, ZCU102); the roadmap's north star
//! is a production system sharding heavy traffic across many backends.
//! This module is the layer above [`SchedCore`] that makes that real:
//! a [`ClusterCore`] owns one scheduler shard per board — each with its
//! *own* fabric model and [`CostModel`](super::core::CostModel), so
//! heterogeneous boards coexist — and a pluggable [`PlacementPolicy`]
//! decides which board every acceleration request lands on.
//!
//! The same two-harness architecture as the per-board core applies:
//! the discrete-event simulator ([`super::simulate_cluster`]) and the
//! live daemon (one `Cynq` per board) both drive this state machine,
//! and the per-shard decision sequences must match verbatim for the
//! same trace (`tests/cluster_parity.rs`).
//!
//! ## Placement policies
//!
//! A policy sees a read-only [`ShardView`] per board — residency
//! (which accelerators are configured where), queued-tile backlog and
//! running count — and routes one [`RouteReq`]:
//!
//! - [`RoundRobin`] — the baseline: boards in rotation, blind to state.
//! - [`LeastLoaded`] — the board with the smallest backlog + running
//!   load (ties to the lowest index).
//! - [`Locality`] — prefer boards whose regions *already hold* the
//!   request's accelerator (no partial reconfiguration on dispatch),
//!   falling back to least-loaded when nothing is resident or every
//!   resident board's backlog exceeds [`Locality::backlog_limit`].
//!
//! ## Work stealing
//!
//! Routing happens at admission; load changes afterwards.  To keep a
//! drained board from idling while another shard's queue is deep, the
//! harness calls [`ClusterCore::steal_into`] before each board's
//! scheduling round: a fully idle shard (no queue, nothing running)
//! pulls the most recently queued request from the shard with the
//! largest backlog above [`ClusterCore::steal_threshold`].  Requests
//! carrying a checkpoint are never stolen — their register-file
//! snapshot lives on the donor board's hardware.  Both harnesses call
//! the hook at the same point in the round lifecycle, so stealing
//! never breaks decision parity.

use super::core::{
    Decision, Policy, RegionMap, Request, SchedCore, SchedCounters, TenantSchedCounters,
};
use crate::accel::Catalog;
use crate::shell::{Shell, ShellBoard};
use std::collections::{BTreeMap, VecDeque};

/// Default backlog (queued tiles) past which an overloaded shard
/// becomes a work-stealing donor, and past which [`Locality`] stops
/// packing a resident board.
pub const DEFAULT_STEAL_THRESHOLD: usize = 32;

/// Merged-log ring cap (same order as the per-shard cap): bounded for
/// a long-lived daemon, plenty for tests and benches.
const MERGED_LOG_CAP: usize = 65_536;

/// Built-in placement policy selector (the cluster analogue of
/// [`Policy`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// Boards in rotation, blind to residency and load.
    RoundRobin,
    /// Smallest backlog + running load wins.
    LeastLoaded,
    /// Bitstream-residency affinity with least-loaded fallback.
    Locality,
}

impl PlacementKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementKind::RoundRobin => "round-robin",
            PlacementKind::LeastLoaded => "least-loaded",
            PlacementKind::Locality => "locality",
        }
    }

    fn instantiate(self) -> Box<dyn PlacementPolicy> {
        match self {
            PlacementKind::RoundRobin => Box::<RoundRobin>::default(),
            PlacementKind::LeastLoaded => Box::<LeastLoaded>::default(),
            PlacementKind::Locality => Box::<Locality>::default(),
        }
    }
}

/// Read-only per-shard state handed to placement policies.
pub struct ShardView<'a> {
    pub board: ShellBoard,
    /// The shard's region map (residency + busy flags).
    pub regions: &'a RegionMap,
    /// Queued tiles across every user of this shard.
    pub backlog_tiles: usize,
    /// Queued requests.
    pub pending: usize,
    /// In-flight dispatches.
    pub running: usize,
}

impl ShardView<'_> {
    /// An instance of `accel` is configured somewhere on this board
    /// (idle or busy) — dispatching there can reuse it or at least
    /// avoid a cold load later.
    pub fn holds(&self, accel: &str) -> bool {
        self.regions
            .iter()
            .any(|r| r.loaded.as_ref().map(|l| l.accel == accel).unwrap_or(false))
    }

    /// Scalar load signal: queued tiles plus in-flight dispatches.
    pub fn load(&self) -> usize {
        self.backlog_tiles + self.running
    }
}

/// The request a placement policy is asked to route.
pub struct RouteReq<'a> {
    pub user: usize,
    /// Tenant the request is accounted to (defaults to `user`) — lets
    /// tenant-share-aware placements keep one tenant's requests from
    /// crowding a single board.
    pub tenant: usize,
    /// The tenant's QoS weight ([`ClusterCore::set_tenant_weight`]).
    pub weight: u32,
    pub accel: &'a str,
    pub tiles: usize,
}

/// A pluggable board-placement strategy.  Must be deterministic for a
/// given (shard states, request) pair — both harnesses route at
/// admission and their decisions must agree (cluster parity).
pub trait PlacementPolicy: Send {
    /// Stable identifier (reporting + daemon configuration).
    fn name(&self) -> &'static str;

    /// Board index for `req`.  `shards` is never empty; the returned
    /// index is clamped by the caller.
    fn route(&mut self, shards: &[ShardView<'_>], req: &RouteReq<'_>) -> usize;
}

/// Boards in strict rotation — the baseline every smarter policy is
/// judged against (fig23).
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl PlacementPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, shards: &[ShardView<'_>], _req: &RouteReq<'_>) -> usize {
        let b = self.next % shards.len();
        self.next = (b + 1) % shards.len();
        b
    }
}

/// The board with the smallest backlog + running load (ties break to
/// the lowest index for determinism).
#[derive(Debug, Default)]
pub struct LeastLoaded;

fn least_loaded(shards: &[ShardView<'_>]) -> usize {
    shards
        .iter()
        .enumerate()
        .min_by_key(|(i, s)| (s.load(), *i))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

impl PlacementPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(&mut self, shards: &[ShardView<'_>], _req: &RouteReq<'_>) -> usize {
        least_loaded(shards)
    }
}

/// Bitstream-residency affinity: prefer the least-loaded board that
/// already holds the request's accelerator — dispatching there avoids
/// a partial reconfiguration — unless every such board's backlog
/// exceeds [`Locality::backlog_limit`], in which case fall back to
/// least-loaded (the spill then seeds residency on a fresh board, and
/// work stealing drains any imbalance that still builds up).
#[derive(Debug)]
pub struct Locality {
    /// Queued-tile backlog past which a resident board is considered
    /// saturated and the request spills to the least-loaded board.
    pub backlog_limit: usize,
}

impl Default for Locality {
    fn default() -> Locality {
        Locality { backlog_limit: DEFAULT_STEAL_THRESHOLD }
    }
}

impl PlacementPolicy for Locality {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn route(&mut self, shards: &[ShardView<'_>], req: &RouteReq<'_>) -> usize {
        let resident = shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.holds(req.accel) && s.backlog_tiles < self.backlog_limit)
            .min_by_key(|(i, s)| (s.load(), *i))
            .map(|(i, _)| i);
        resident.unwrap_or_else(|| least_loaded(shards))
    }
}

/// Cluster-level counters (the per-shard [`SchedCounters`] live in
/// each shard's core).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ClusterCounters {
    /// Requests routed to a board at admission.
    pub routed: u64,
    /// Requests moved between shards by work stealing.
    pub steals: u64,
}

struct Shard {
    board: ShellBoard,
    core: SchedCore,
}

/// N per-board scheduler shards behind one placement policy — the
/// state machine both the cluster simulator and the multi-fabric
/// daemon drive.  All per-board scheduling intelligence stays in each
/// shard's [`SchedCore`]; this type owns only *routing* (admission),
/// *stealing* (rebalance) and the merged decision log.
pub struct ClusterCore {
    shards: Vec<Shard>,
    placement: Box<dyn PlacementPolicy>,
    steal_threshold: usize,
    counters: ClusterCounters,
    /// Per-tenant QoS weights, mirrored into every shard and handed to
    /// the placement policy through [`RouteReq`].
    tenant_weights: BTreeMap<usize, u32>,
    /// (board, decision) in global dispatch order, ring-capped.
    merged: VecDeque<(usize, Decision)>,
    merged_dropped: u64,
}

impl ClusterCore {
    /// Build a cluster of `boards` (one shard per entry, heterogeneous
    /// mixes welcome) with a built-in placement policy.
    pub fn new(
        boards: &[ShellBoard],
        catalog: &Catalog,
        default: Policy,
        placement: PlacementKind,
    ) -> ClusterCore {
        Self::with_placement(boards, catalog, default, placement.instantiate())
    }

    /// [`ClusterCore::new`] with a custom [`PlacementPolicy`].
    pub fn with_placement(
        boards: &[ShellBoard],
        catalog: &Catalog,
        default: Policy,
        placement: Box<dyn PlacementPolicy>,
    ) -> ClusterCore {
        assert!(!boards.is_empty(), "a cluster needs at least one board");
        ClusterCore {
            shards: boards
                .iter()
                .map(|&board| Shard {
                    board,
                    core: SchedCore::new(&Shell::build(board), catalog.clone(), default),
                })
                .collect(),
            placement,
            steal_threshold: DEFAULT_STEAL_THRESHOLD,
            counters: ClusterCounters::default(),
            tenant_weights: BTreeMap::new(),
            merged: VecDeque::new(),
            merged_dropped: 0,
        }
    }

    /// Set a tenant's QoS weight on every shard (and for routing).
    pub fn set_tenant_weight(&mut self, tenant: usize, weight: u32) {
        self.tenant_weights.insert(tenant, weight.max(1));
        for s in &mut self.shards {
            s.core.set_tenant_weight(tenant, weight);
        }
    }

    /// Override the work-stealing donor threshold (queued tiles).
    pub fn with_steal_threshold(mut self, tiles: usize) -> ClusterCore {
        self.steal_threshold = tiles;
        self
    }

    /// Number of boards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn board(&self, b: usize) -> ShellBoard {
        self.shards[b].board
    }

    /// Read-only access to one shard's scheduler core (decision log,
    /// counters, region map, catalog).
    pub fn core(&self, b: usize) -> &SchedCore {
        &self.shards[b].core
    }

    /// Mutable access to one shard's core — for registering custom
    /// per-shard [`super::SchedPolicy`] implementations before traffic
    /// starts.  Mutating queues mid-flight voids decision parity.
    pub fn core_mut(&mut self, b: usize) -> &mut SchedCore {
        &mut self.shards[b].core
    }

    pub fn placement_name(&self) -> &'static str {
        self.placement.name()
    }

    pub fn cluster_counters(&self) -> &ClusterCounters {
        &self.counters
    }

    /// Sum of every shard's [`SchedCounters`] — what aggregate stats
    /// report.
    pub fn total_counters(&self) -> SchedCounters {
        let mut t = SchedCounters::default();
        for s in &self.shards {
            let c = s.core.counters();
            t.reconfigs += c.reconfigs;
            t.reuses += c.reuses;
            t.skips += c.skips;
            t.replications += c.replications;
            t.preemptions += c.preemptions;
            t.resumes += c.resumes;
        }
        t
    }

    /// Route one request to a board and enqueue it there.  Admission
    /// errors (unknown accelerator/variant) surface before routing, so
    /// a rejection never perturbs the placement policy's state.
    /// Returns the board index the request landed on.  Accounted to
    /// tenant `user`; the daemon's admission pipeline routes through
    /// [`ClusterCore::submit_for`].
    pub fn submit(
        &mut self,
        user: usize,
        job: u64,
        accel: &str,
        tiles: usize,
        pin: Option<&str>,
    ) -> Result<usize, String> {
        self.submit_for(user, user, job, accel, tiles, pin)
    }

    /// [`ClusterCore::submit`] with an explicit tenant tag.
    pub fn submit_for(
        &mut self,
        user: usize,
        tenant: usize,
        job: u64,
        accel: &str,
        tiles: usize,
        pin: Option<&str>,
    ) -> Result<usize, String> {
        // Validate against shard 0's catalog first (all shards share
        // one catalog): a rejected request must not advance RoundRobin.
        self.shards[0].core.validate(accel, pin)?;
        let views: Vec<ShardView<'_>> = self
            .shards
            .iter()
            .map(|s| ShardView {
                board: s.board,
                regions: s.core.regions(),
                backlog_tiles: s.core.backlog_tiles(),
                pending: s.core.pending(),
                running: s.core.running_count(),
            })
            .collect();
        let weight = self.tenant_weights.get(&tenant).copied().unwrap_or(1);
        let req = RouteReq { user, tenant, weight, accel, tiles };
        let b = self.placement.route(&views, &req).min(self.shards.len() - 1);
        self.shards[b].core.submit_for(user, tenant, job, accel, tiles, pin)?;
        self.counters.routed += 1;
        Ok(b)
    }

    /// Per-tenant scheduling counters summed across every shard.
    pub fn tenant_counters(&self) -> BTreeMap<usize, TenantSchedCounters> {
        let mut out: BTreeMap<usize, TenantSchedCounters> = BTreeMap::new();
        for s in &self.shards {
            for (&tenant, c) in s.core.tenant_counters() {
                let t = out.entry(tenant).or_default();
                t.admitted += c.admitted;
                t.completed += c.completed;
                t.preempted += c.preempted;
                t.rejected += c.rejected;
            }
        }
        out
    }

    /// Work-stealing hook — call right before board `b`'s scheduling
    /// round.  A fully idle shard pulls one request from the deepest
    /// *stealable* backlog above the threshold (checkpoint-pinned
    /// remainders don't count — they can never move); `true` when a
    /// request moved.
    pub fn steal_into(&mut self, b: usize) -> bool {
        if self.shards.len() < 2 {
            return false;
        }
        if self.shards[b].core.has_pending() || self.shards[b].core.running_count() > 0 {
            return false;
        }
        let donor = (0..self.shards.len())
            .filter(|&i| i != b)
            .map(|i| (self.shards[i].core.stealable_tiles(), i))
            .filter(|&(tiles, _)| tiles > self.steal_threshold)
            .max_by_key(|&(tiles, i)| (tiles, std::cmp::Reverse(i)))
            .map(|(_, i)| i);
        let Some(donor) = donor else { return false };
        let Some(req) = self.shards[donor].core.steal_back() else { return false };
        self.shards[b].core.inject(req);
        self.counters.steals += 1;
        true
    }

    // ---- per-shard delegation (the harness round lifecycle) ---------

    pub fn begin_round_at(&mut self, b: usize, now: u64) {
        self.shards[b].core.begin_round_at(now);
    }

    /// Next placement on board `b`; also appended to the merged log.
    pub fn next_decision(&mut self, b: usize) -> Option<Decision> {
        let d = self.shards[b].core.next_decision()?;
        if self.merged.len() >= MERGED_LOG_CAP {
            self.merged.pop_front();
            self.merged_dropped += 1;
        }
        self.merged.push_back((b, d.clone()));
        Some(d)
    }

    pub fn complete(&mut self, b: usize, anchor: usize) {
        self.shards[b].core.complete(anchor);
    }

    pub fn evict(&mut self, b: usize, anchor: usize) {
        self.shards[b].core.evict(anchor);
    }

    pub fn mark_running(&mut self, b: usize, d: &Decision, start: u64, end: u64) {
        self.shards[b].core.mark_running(d, start, end);
    }

    pub fn service_ns(&self, b: usize, d: &Decision, concurrent: usize) -> u64 {
        self.shards[b].core.service_ns(d, concurrent)
    }

    pub fn busy_anchors(&self, b: usize) -> usize {
        self.shards[b].core.busy_anchors()
    }

    pub fn take_rejected(&mut self, b: usize) -> Vec<(Request, String)> {
        self.shards[b].core.take_rejected()
    }

    pub fn preempt_tick_due(
        &self,
        b: usize,
        next_tick: &mut Option<u64>,
        now: u64,
    ) -> Option<u64> {
        self.shards[b].core.preempt_tick_due(next_tick, now)
    }

    // ---- cluster-wide queries and tenant lifecycle ------------------

    /// Requests queued across every shard.
    pub fn pending(&self) -> usize {
        self.shards.iter().map(|s| s.core.pending()).sum()
    }

    pub fn has_pending(&self) -> bool {
        self.shards.iter().any(|s| s.core.has_pending())
    }

    /// In-flight dispatches across every shard.
    pub fn running_total(&self) -> usize {
        self.shards.iter().map(|s| s.core.running_count()).sum()
    }

    /// Route `user` to the scheduling policy named `name` on every
    /// shard; `false` if the name is unknown (all shards share the
    /// built-in registry, so the answer is uniform).
    pub fn set_user_policy(&mut self, user: usize, name: &str) -> bool {
        let mut ok = true;
        for s in &mut self.shards {
            ok &= s.core.set_user_policy(user, name);
        }
        ok
    }

    pub fn policy_name_of(&self, user: usize) -> &'static str {
        self.shards[0].core.policy_name_of(user)
    }

    /// Retire `user` on every shard; returns the dropped queued
    /// requests tagged with the shard they were queued on (the daemon
    /// fails their replies and drops per-board snapshots).
    pub fn retire_user(&mut self, user: usize) -> Vec<(usize, Request)> {
        let mut out = Vec::new();
        for (b, s) in self.shards.iter_mut().enumerate() {
            out.extend(s.core.retire_user(user).into_iter().map(|r| (b, r)));
        }
        out
    }

    /// Drain every queued request on every shard (stall guard).
    pub fn drain_pending(&mut self) -> Vec<(usize, Request)> {
        let mut out = Vec::new();
        for (b, s) in self.shards.iter_mut().enumerate() {
            out.extend(s.core.drain_pending().into_iter().map(|r| (b, r)));
        }
        out
    }

    /// The merged `(board, decision)` log in global dispatch order.
    pub fn merged_log(&self) -> impl Iterator<Item = &(usize, Decision)> {
        self.merged.iter()
    }

    /// The last `n` merged entries — O(1) positioning.
    pub fn merged_log_tail(&self, n: usize) -> impl Iterator<Item = &(usize, Decision)> {
        self.merged.iter().skip(self.merged.len().saturating_sub(n))
    }

    pub fn merged_dropped(&self) -> u64 {
        self.merged_dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::DecisionKind;

    fn catalog() -> Catalog {
        Catalog::load_default().unwrap()
    }

    fn cluster(n: usize, kind: PlacementKind) -> ClusterCore {
        let boards: Vec<ShellBoard> = (0..n)
            .map(|i| if i % 2 == 0 { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 })
            .collect();
        ClusterCore::new(&boards, &catalog(), Policy::Elastic, kind)
    }

    /// Drive one shard's round to completion, replaying completions
    /// immediately (run-to-completion harness stand-in).
    fn drain_board(c: &mut ClusterCore, b: usize, now: u64) -> Vec<Decision> {
        c.begin_round_at(b, now);
        let mut out = Vec::new();
        while let Some(d) = c.next_decision(b) {
            assert_ne!(d.kind, DecisionKind::Preempt);
            let lat = c.service_ns(b, &d, c.busy_anchors(b).saturating_sub(1));
            c.mark_running(b, &d, now, now + lat.max(1));
            out.push(d);
        }
        for d in &out {
            c.complete(b, d.anchor);
        }
        out
    }

    #[test]
    fn round_robin_rotates_boards() {
        let mut c = cluster(3, PlacementKind::RoundRobin);
        let mut routed = Vec::new();
        for j in 0..6 {
            routed.push(c.submit(0, j, "vadd", 1, None).unwrap());
        }
        assert_eq!(routed, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(c.cluster_counters().routed, 6);
    }

    #[test]
    fn least_loaded_prefers_empty_board() {
        let mut c = cluster(2, PlacementKind::LeastLoaded);
        let b0 = c.submit(0, 0, "mandelbrot", 10, None).unwrap();
        assert_eq!(b0, 0, "tie breaks to the lowest index");
        let b1 = c.submit(1, 1, "sobel", 1, None).unwrap();
        assert_eq!(b1, 1, "board 0 carries 10 queued tiles");
    }

    #[test]
    fn locality_prefers_resident_board() {
        let mut c = cluster(2, PlacementKind::Locality);
        // Nothing resident yet: least-loaded → board 0; run it so the
        // sobel module becomes resident there.
        assert_eq!(c.submit(0, 0, "sobel", 1, Some("sobel_v1")).unwrap(), 0);
        drain_board(&mut c, 0, 0);
        // Queue more sobel on the resident board, making it the
        // *heavier* board; an unrelated accelerator routes least-loaded
        // to the empty board 1.
        assert_eq!(c.submit(0, 1, "sobel", 8, Some("sobel_v1")).unwrap(), 0);
        assert_eq!(c.submit(1, 2, "mandelbrot", 1, None).unwrap(), 1);
        // Locality: sobel keeps routing to its resident board even
        // though board 1 now carries less queued work than board 0.
        assert_eq!(c.submit(0, 3, "sobel", 1, Some("sobel_v1")).unwrap(), 0);
        // And the resident instance is reused, not reconfigured.
        c.begin_round_at(0, 1);
        let d = c.next_decision(0).unwrap();
        assert!(!d.reconfigure, "resident instance must be reused: {d:?}");
    }

    #[test]
    fn locality_spills_past_backlog_limit() {
        let mut c = cluster(2, PlacementKind::Locality);
        assert_eq!(c.submit(0, 0, "sobel", 1, Some("sobel_v1")).unwrap(), 0);
        drain_board(&mut c, 0, 0);
        // Saturate the resident board past the default limit: the next
        // sobel request spills to the least-loaded board instead.
        assert_eq!(
            c.submit(0, 1, "sobel", DEFAULT_STEAL_THRESHOLD + 1, Some("sobel_v1")).unwrap(),
            0
        );
        assert_eq!(c.submit(0, 2, "sobel", 1, Some("sobel_v1")).unwrap(), 1);
    }

    #[test]
    fn idle_board_steals_from_deep_backlog() {
        let mut c = cluster(2, PlacementKind::LeastLoaded).with_steal_threshold(8);
        // Board 0: deep backlog; board 1: idle.
        for j in 0..4 {
            c.shards[0].core.submit(0, j, "vadd", 8, None).unwrap();
        }
        assert!(c.steal_into(1), "idle board must steal");
        assert_eq!(c.cluster_counters().steals, 1);
        assert_eq!(c.core(1).pending(), 1);
        assert_eq!(c.core(0).pending(), 3);
        // A busy board never steals.
        assert!(!c.steal_into(0));
        // Below the threshold, nothing moves.
        let mut c2 = cluster(2, PlacementKind::LeastLoaded).with_steal_threshold(1000);
        c2.shards[0].core.submit(0, 0, "vadd", 8, None).unwrap();
        assert!(!c2.steal_into(1));
    }

    #[test]
    fn rejection_does_not_advance_round_robin() {
        let mut c = cluster(2, PlacementKind::RoundRobin);
        assert!(c.submit(0, 0, "flux_capacitor", 1, None).is_err());
        assert!(c.submit(0, 1, "vadd", 1, Some("vadd_v9")).is_err());
        assert_eq!(c.cluster_counters().routed, 0);
        // First accepted request still lands on board 0.
        assert_eq!(c.submit(0, 2, "vadd", 1, None).unwrap(), 0);
    }

    #[test]
    fn merged_log_tags_boards() {
        let mut c = cluster(2, PlacementKind::RoundRobin);
        c.submit(0, 0, "vadd", 1, None).unwrap();
        c.submit(1, 1, "dct", 1, None).unwrap();
        drain_board(&mut c, 0, 0);
        drain_board(&mut c, 1, 0);
        let merged: Vec<(usize, String)> = c
            .merged_log()
            .map(|(b, d)| (*b, d.accel.clone()))
            .collect();
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0], (0, "vadd".to_string()));
        assert_eq!(merged[1], (1, "dct".to_string()));
        // Per-shard logs partition the merged log.
        assert_eq!(c.core(0).decision_log().count(), 1);
        assert_eq!(c.core(1).decision_log().count(), 1);
        // Tail query returns only the newest entries.
        assert_eq!(c.merged_log_tail(1).count(), 1);
        assert_eq!(c.merged_log_tail(1).next().unwrap().0, 1);
    }

    #[test]
    fn retire_and_drain_tag_boards() {
        let mut c = cluster(2, PlacementKind::RoundRobin);
        c.submit(0, 0, "vadd", 1, None).unwrap(); // board 0
        c.submit(0, 1, "vadd", 1, None).unwrap(); // board 1
        let retired = c.retire_user(0);
        let boards: Vec<usize> = retired.iter().map(|(b, _)| *b).collect();
        assert_eq!(boards, vec![0, 1]);
        assert!(!c.has_pending());
        c.submit(1, 2, "dct", 1, None).unwrap();
        assert_eq!(c.drain_pending().len(), 1);
    }
}
