//! # Deterministic fault injection (the reliability scenario axis)
//!
//! FOS's pitch is modularity that survives "changing workloads"; a
//! production cluster must also survive a changing *substrate* —
//! reconfigurations that fail, boards that drop out mid-round,
//! transient execution glitches.  This module is the injection half of
//! the failure domain: a [`FaultPlan`] is a pure, seedable description
//! of what goes wrong and when, consumed **identically** by the
//! discrete-event simulator ([`super::simulate_cluster`]) and the
//! daemon's virtual-time dispatcher, so a fault scenario validated
//! offline replays bit-for-bit on the live path
//! (`tests/cluster_parity.rs`, fault-parity).
//!
//! Three fault kinds:
//!
//! - **Board outages** ([`Outage`]) — board `b` goes
//!   [`Down`](super::cluster::BoardHealth::Down) at virtual time
//!   `at_ns` and revives `duration_ns` later.  The recovery half lives
//!   in [`ClusterCore::mark_board_down`](super::ClusterCore::mark_board_down):
//!   running work is drained through the checkpoint store and migrated
//!   to healthy shards with its progress preserved.
//! - **Reconfiguration failures** — the `k`-th partial-reconfiguration
//!   attempt on board `b` fails when a seed-derived draw lands under
//!   the plan's reconfiguration failure rate
//!   ([`FaultPlan::with_reconfig_rate`]).  Recovery: exponential-backoff
//!   retries with a per-accelerator failure cap
//!   ([`ClusterCore::reconfig_outcome`](super::ClusterCore::reconfig_outcome)).
//! - **Transient run errors** — the `k`-th dispatch *completion* on
//!   board `b` fails likewise; the dispatch's work is lost and the
//!   request re-queued at the front of its owner's queue
//!   ([`ClusterCore::fail_run`](super::ClusterCore::fail_run)).
//!
//! ## Determinism contract
//!
//! No wall clock, no shared RNG stream: every draw is a pure function
//! `splitmix(seed ^ mix(kind, board, attempt))`, and the only mutable
//! state is the per-board attempt counters.  Because the two harnesses
//! make identical decision sequences, they consult the plan with
//! identical `(board, attempt)` arguments in identical order — the
//! injected fault sequence can never diverge between them.

use crate::testutil::Rng;

/// One board outage: `board` fails at virtual `at_ns` and revives at
/// `at_ns + duration_ns`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Outage {
    pub board: usize,
    pub at_ns: u64,
    pub duration_ns: u64,
}

impl Outage {
    pub fn revive_at_ns(&self) -> u64 {
        self.at_ns.saturating_add(self.duration_ns)
    }
}

/// Domain separators for the per-kind draw streams (arbitrary odd
/// constants; only inequality matters).
const DOMAIN_RECONFIG: u64 = 0x5265_636F_6E66_6731;
const DOMAIN_RUN: u64 = 0x5472_616E_7369_656E;

/// A deterministic, seedable fault schedule — see the module docs.
/// Cheap to clone (tests clone one plan into both harnesses).
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    seed: u64,
    /// Probability that any given reconfiguration attempt fails.
    reconfig_rate: f64,
    /// Probability that any given dispatch completion fails.
    run_rate: f64,
    outages: Vec<Outage>,
    /// Per-board reconfiguration attempts consumed so far.
    reconfig_attempts: Vec<u64>,
    /// Per-board dispatch completions consumed so far.
    completions: Vec<u64>,
}

impl FaultPlan {
    /// An empty plan (no faults) with the draw-stream seed `seed`.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Add one board outage.
    pub fn with_outage(mut self, board: usize, at_ns: u64, duration_ns: u64) -> FaultPlan {
        self.outages.push(Outage { board, at_ns, duration_ns });
        self.outages.sort_by_key(|o| (o.at_ns, o.board));
        self
    }

    /// Fail each reconfiguration attempt with probability `rate`.
    pub fn with_reconfig_rate(mut self, rate: f64) -> FaultPlan {
        self.reconfig_rate = rate.clamp(0.0, 1.0);
        self
    }

    /// Fail each dispatch completion with probability `rate`.
    pub fn with_run_rate(mut self, rate: f64) -> FaultPlan {
        self.run_rate = rate.clamp(0.0, 1.0);
        self
    }

    /// A seed-derived chaos mix over `boards` boards and a virtual
    /// `horizon_ns`: one mid-run outage (fails in the middle half of
    /// the horizon, down for an eighth to a quarter of it) plus small
    /// seed-derived reconfiguration / transient-run failure rates.
    /// The chaos property suite (`tests/chaos.rs`) sweeps seeds of
    /// this generator.
    pub fn chaos(seed: u64, boards: usize, horizon_ns: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xC4A0_5C4A_05C4_A05C);
        let h = horizon_ns.max(8);
        let board = rng.below(boards.max(1) as u64) as usize;
        let at = h / 4 + rng.below((h / 4).max(1));
        let dur = h / 8 + rng.below((h / 8).max(1));
        FaultPlan::new(seed)
            .with_outage(board, at, dur)
            .with_reconfig_rate(rng.f64() * 0.15)
            .with_run_rate(rng.f64() * 0.10)
    }

    /// Parse a CLI spec (`fos daemon --fault-plan <spec>`): comma- or
    /// semicolon-separated `key=value` entries —
    ///
    /// - `seed=N` — draw-stream seed (default 0)
    /// - `reconfig=R` — reconfiguration failure probability (0..1)
    /// - `run=R` — transient run-error probability (0..1)
    /// - `down=B@T+D` — board `B` down at virtual time `T` for `D`
    ///   (repeatable).  `T`/`D` are milliseconds, or exact nanoseconds
    ///   with an `ns` suffix — [`FaultPlan::to_spec`] emits the latter
    ///   so a repro artifact replays bit-identically.
    ///
    /// e.g. `seed=7,reconfig=0.05,down=1@50+40`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split([',', ';']).filter(|p| !p.trim().is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault-plan entry {part:?} is not key=value"))?;
            let (key, value) = (key.trim(), value.trim());
            match key {
                "seed" => {
                    plan.seed =
                        value.parse().map_err(|_| format!("bad seed {value:?}"))?;
                }
                "reconfig" => {
                    let r: f64 =
                        value.parse().map_err(|_| format!("bad reconfig rate {value:?}"))?;
                    plan.reconfig_rate = r.clamp(0.0, 1.0);
                }
                "run" => {
                    let r: f64 =
                        value.parse().map_err(|_| format!("bad run rate {value:?}"))?;
                    plan.run_rate = r.clamp(0.0, 1.0);
                }
                "down" => {
                    // B@T+D; T and D in ms, or exact ns with a suffix.
                    let (board, rest) = value
                        .split_once('@')
                        .ok_or_else(|| format!("bad outage {value:?} (want B@T+D)"))?;
                    let (at, dur) = rest
                        .split_once('+')
                        .ok_or_else(|| format!("bad outage {value:?} (want B@T+D)"))?;
                    let parse_time = |t: &str| -> Result<u64, String> {
                        match t.strip_suffix("ns") {
                            Some(ns) => {
                                ns.parse().map_err(|_| format!("bad outage time {t:?}"))
                            }
                            None => t
                                .parse::<u64>()
                                .ok()
                                .and_then(|ms| ms.checked_mul(1_000_000))
                                .ok_or_else(|| format!("bad outage time {t:?}")),
                        }
                    };
                    let board: usize =
                        board.parse().map_err(|_| format!("bad board {board:?}"))?;
                    plan = plan.with_outage(board, parse_time(at)?, parse_time(dur)?);
                }
                other => return Err(format!("unknown fault-plan key {other:?}")),
            }
        }
        Ok(plan)
    }

    /// Render the plan back to the [`FaultPlan::parse`] spec format —
    /// what the chaos suite writes into failure repro artifacts.
    pub fn to_spec(&self) -> String {
        let mut out = vec![format!("seed={}", self.seed)];
        if self.reconfig_rate > 0.0 {
            out.push(format!("reconfig={}", self.reconfig_rate));
        }
        if self.run_rate > 0.0 {
            out.push(format!("run={}", self.run_rate));
        }
        for o in &self.outages {
            // Exact nanoseconds: a repro artifact must replay
            // bit-identically, never rounded to milliseconds.
            out.push(format!("down={}@{}ns+{}ns", o.board, o.at_ns, o.duration_ns));
        }
        out.join(",")
    }

    /// The scheduled outages, `(at_ns, board)` ascending.  Harnesses
    /// turn each into a pair of virtual-time events (down at `at_ns`,
    /// revive at [`Outage::revive_at_ns`]).
    pub fn outages(&self) -> &[Outage] {
        &self.outages
    }

    pub fn reconfig_rate(&self) -> f64 {
        self.reconfig_rate
    }

    pub fn run_rate(&self) -> f64 {
        self.run_rate
    }

    /// `true` when the plan can inject anything at all.
    pub fn is_armed(&self) -> bool {
        !self.outages.is_empty() || self.reconfig_rate > 0.0 || self.run_rate > 0.0
    }

    /// Pure draw: splitmix over `(seed, domain, board, attempt)`.
    fn draw(&self, domain: u64, board: usize, attempt: u64) -> f64 {
        let mix = domain
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((board as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .wrapping_add(attempt.wrapping_mul(0x94D0_49BB_1331_11EB));
        Rng::new(self.seed ^ mix).f64()
    }

    fn counter(v: &mut Vec<u64>, board: usize) -> &mut u64 {
        if v.len() <= board {
            v.resize(board + 1, 0);
        }
        &mut v[board]
    }

    /// Consume one reconfiguration attempt on `board`: `true` when the
    /// injected fault schedule fails it.  Call exactly once per
    /// `reconfigure` decision, in dispatch order — both harnesses do,
    /// which is the whole parity contract.
    pub fn reconfig_should_fail(&mut self, board: usize) -> bool {
        let k = Self::counter(&mut self.reconfig_attempts, board);
        let attempt = *k;
        *k += 1;
        self.reconfig_rate > 0.0 && self.draw(DOMAIN_RECONFIG, board, attempt) < self.reconfig_rate
    }

    /// Consume one dispatch completion on `board`: `true` when the
    /// schedule injects a transient run error (the dispatch's work is
    /// lost; the request must be re-queued).  Call exactly once per
    /// non-cancelled completion, in completion order.
    pub fn run_should_fail(&mut self, board: usize) -> bool {
        let k = Self::counter(&mut self.completions, board);
        let attempt = *k;
        *k += 1;
        self.run_rate > 0.0 && self.draw(DOMAIN_RUN, board, attempt) < self.run_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_are_deterministic_and_seed_sensitive() {
        let seq = |seed: u64| -> Vec<bool> {
            let mut p = FaultPlan::new(seed).with_reconfig_rate(0.5).with_run_rate(0.5);
            (0..32)
                .flat_map(|_| [p.reconfig_should_fail(0), p.run_should_fail(1)])
                .collect()
        };
        assert_eq!(seq(7), seq(7), "same seed must replay identically");
        assert_ne!(seq(7), seq(8), "different seeds must differ");
        // Two clones consume independent counters but identical draws —
        // the sim/daemon consumption model.
        let plan = FaultPlan::new(3).with_reconfig_rate(0.3);
        let (mut a, mut b) = (plan.clone(), plan);
        for _ in 0..64 {
            assert_eq!(a.reconfig_should_fail(2), b.reconfig_should_fail(2));
        }
    }

    #[test]
    fn rates_bound_behaviour() {
        let mut never = FaultPlan::new(1);
        let mut always = FaultPlan::new(1).with_reconfig_rate(1.0).with_run_rate(1.0);
        for _ in 0..100 {
            assert!(!never.reconfig_should_fail(0));
            assert!(!never.run_should_fail(0));
            assert!(always.reconfig_should_fail(0));
            assert!(always.run_should_fail(0));
        }
    }

    #[test]
    fn parse_and_spec_roundtrip() {
        let p = FaultPlan::parse("seed=7,reconfig=0.05,run=0.02,down=1@50+40,down=0@10+5")
            .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.reconfig_rate(), 0.05);
        assert_eq!(p.run_rate(), 0.02);
        assert_eq!(
            p.outages(),
            &[
                Outage { board: 0, at_ns: 10_000_000, duration_ns: 5_000_000 },
                Outage { board: 1, at_ns: 50_000_000, duration_ns: 40_000_000 },
            ]
        );
        assert!(p.is_armed());
        // Spec render re-parses to the same plan — exactly, including
        // ns-precision outage times that don't fall on ms boundaries.
        let p2 = FaultPlan::parse(&p.to_spec()).unwrap();
        assert_eq!(p2.outages(), p.outages());
        assert_eq!(p2.reconfig_rate(), p.reconfig_rate());
        let odd = FaultPlan::new(0).with_outage(2, 1_234_567, 7_654_321);
        let odd2 = FaultPlan::parse(&odd.to_spec()).unwrap();
        assert_eq!(odd2.outages(), odd.outages(), "ns precision must round-trip");
        // Bad specs are structured errors, not panics.
        assert!(FaultPlan::parse("warp=1").is_err());
        assert!(FaultPlan::parse("down=1@xx+3").is_err());
        assert!(FaultPlan::parse("down=nope").is_err());
        // An ms value whose ns conversion overflows is a structured
        // error, not a panic or a wrapped bogus time.
        assert!(FaultPlan::parse("down=0@99999999999999999+1").is_err());
        // Empty spec = empty plan.
        assert!(!FaultPlan::parse("").unwrap().is_armed());
    }

    #[test]
    fn chaos_generator_is_deterministic_and_in_horizon() {
        let a = FaultPlan::chaos(5, 4, 1_000_000);
        let b = FaultPlan::chaos(5, 4, 1_000_000);
        assert_eq!(a.outages(), b.outages());
        assert_eq!(a.outages().len(), 1);
        let o = a.outages()[0];
        assert!(o.board < 4);
        assert!(o.at_ns >= 250_000 && o.at_ns < 500_000, "{o:?}");
        assert!(o.duration_ns >= 125_000 && o.duration_ns < 250_000, "{o:?}");
    }
}
