//! # The scenario engine (trace-driven workloads + ordering fuzz)
//!
//! FOS's pitch is arbitrating the fabric for *dynamic* workloads; the
//! synthetic mixes in [`super::workload`] cannot express the bursty,
//! adversarial tenant behaviour a cloud deployment sees.  This module
//! is the workload half of the scenario layer:
//!
//! - a [`Scenario`] is a compact, versioned **trace** — one record per
//!   arrival (`t_ns, tenant, qos, accel, variant, tiles, stream`) —
//!   with a [`Scenario::parse`] / [`Scenario::to_spec`] ns-exact
//!   round-trip exactly like [`super::FaultPlan`], so a scenario
//!   validated offline replays bit-identically through
//!   [`super::simulate`], [`super::simulate_cluster`] *and* the live
//!   daemon (`fos daemon --scenario <spec>`, bench knob
//!   `FOS_SCENARIO`);
//! - pure seeded **generators** (SplitMix64 draws, no wall clock) for
//!   the canonical cloud shapes: [`Scenario::diurnal`] two-peak load,
//!   [`Scenario::bursts`] correlated multi-tenant bursts,
//!   [`Scenario::flash_crowd`] a quiet baseline plus a synchronized
//!   spike on one hot accelerator, and [`Scenario::heavy_tailed`]
//!   bounded-Pareto job sizes;
//! - an [`OrderStrategy`] — the concurrency-fuzzing hook both
//!   discrete-event harnesses consult at their nondeterminism points
//!   (equal-timestamp event batches, admission ingest boundaries,
//!   preemption-tick cadence).  [`OrderStrategy::Identity`] (the
//!   default) is a no-op at every hook, byte-identical to the fixed
//!   FIFO orderings; [`OrderStrategy::Seeded`] replaces each with a
//!   seeded permutation / bounded jitter, producing a *legal
//!   alternative schedule* that `tests/fuzz_orderings.rs` sweeps for
//!   conservation and parity bugs the fixed orderings hide.
//!
//! ## Determinism contract
//!
//! Like [`super::FaultPlan`], every draw is a pure function of
//! `(seed, domain, key)` — generators never consult a wall clock, and
//! an [`OrderStrategy`] permutation is keyed only by the virtual
//! timestamp (and board) of the hook that requests it.  Because the
//! simulator and the daemon reach each hook with identical batch
//! contents at identical virtual times, a *shared* strategy yields
//! identical permutations on both paths — so sim/daemon decision
//! parity holds under **any** seeded ordering, which is exactly the
//! invariant the fuzz suite leans on.

use super::admission::QosClass;
use super::core::PREEMPT_TICK_NS;
use super::workload::{JobSpec, Workload};
use crate::testutil::Rng;
use std::collections::BTreeMap;

/// Domain separators for the generator / permutation draw streams
/// (arbitrary constants; only inequality matters).
const DOMAIN_DIURNAL: u64 = 0x4469_7572_6E61_6C31;
const DOMAIN_BURSTS: u64 = 0x4275_7273_7453_6571;
const DOMAIN_FLASH: u64 = 0x466C_6173_6843_7277;
const DOMAIN_PARETO: u64 = 0x5061_7265_746F_3133;
const DOMAIN_EVENTS: u64 = 0x4576_656E_744F_7264;
const DOMAIN_INGEST: u64 = 0x496E_6765_7374_5278;
const DOMAIN_TICK: u64 = 0x5469_636B_4A69_7474;

/// Upper bound of the seeded preemption-tick jitter: a fuzzed tick may
/// land up to a quarter-cadence late.  Strictly additive — a jittered
/// tick never fires *before* the core-owned due time, so the rule
/// "re-check after at least `PREEMPT_TICK_NS`" survives fuzzing.
pub const TICK_JITTER_MAX_NS: u64 = PREEMPT_TICK_NS / 4;

/// The accelerator pool the generators draw from (all present in the
/// default catalog, all with a pinnable 1-region `_v1` variant).
const GEN_ACCELS: [&str; 4] = ["sobel", "dct", "fir", "vadd"];

/// One arrival record of a scenario trace: at virtual `t_ns`, tenant
/// `tenant` (DRR weight `qos`) submits a job of `stream` independent
/// requests, `tiles` work items each, on `accel` (optionally pinned to
/// `variant`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScenarioEvent {
    pub t_ns: u64,
    pub tenant: usize,
    /// The tenant's DRR weight at lowering time (the last record of a
    /// tenant wins — a trace can re-weight a tenant mid-stream).
    pub qos: u32,
    pub accel: String,
    /// Pin a specific implementation variant (`None` = elastic pick).
    pub variant: Option<String>,
    pub tiles: usize,
    /// Independent requests in this arrival (the job's parallelism).
    pub stream: usize,
}

/// A deterministic, seedable workload trace — see the module docs.
/// Cheap to clone (tests clone one scenario into both harnesses).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scenario {
    seed: u64,
    /// Uniform per-tenant in-flight quota carried by the trace
    /// (`usize::MAX` = unlimited, the permissive default).
    inflight: usize,
    events: Vec<ScenarioEvent>,
}

impl Default for Scenario {
    fn default() -> Scenario {
        Scenario { seed: 0, inflight: usize::MAX, events: Vec::new() }
    }
}

impl Scenario {
    /// An empty trace with the generator/draw seed `seed`.
    pub fn new(seed: u64) -> Scenario {
        Scenario { seed, ..Scenario::default() }
    }

    /// Append one arrival record.  Records are kept in insertion
    /// order; at equal `t_ns` that order is the tie-break both
    /// harnesses replay (the spec round-trip preserves it exactly).
    pub fn with_event(mut self, e: ScenarioEvent) -> Scenario {
        self.events.push(e);
        self
    }

    /// Give every tenant the same in-flight quota when lowering.
    pub fn with_inflight(mut self, max_inflight: usize) -> Scenario {
        self.inflight = max_inflight.max(1);
        self
    }

    fn from_events(seed: u64, mut events: Vec<ScenarioEvent>) -> Scenario {
        // Stable by arrival time: generation order is the tie-break at
        // equal timestamps, exactly what the spec round-trip preserves.
        events.sort_by_key(|e| e.t_ns);
        Scenario { seed, inflight: usize::MAX, events }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn events(&self) -> &[ScenarioEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Total acceleration requests the trace carries.
    pub fn total_requests(&self) -> usize {
        self.events.iter().map(|e| e.stream).sum()
    }

    /// Diurnal load: `jobs` arrivals over `horizon_ns` drawn by
    /// thinning against a two-peak rate curve (the morning/evening
    /// shape), tenants weighted `1 + tenant % 3`.
    pub fn diurnal(seed: u64, tenants: usize, jobs: usize, horizon_ns: u64) -> Scenario {
        let mut rng = Rng::new(seed ^ DOMAIN_DIURNAL);
        let tenants = tenants.max(1);
        let h = horizon_ns.max(1);
        let mut events = Vec::with_capacity(jobs);
        for _ in 0..jobs {
            // Rejection sampling against rate(t) in [0.25, 1]: two full
            // cosine troughs over the horizon = two acceptance peaks.
            let t = loop {
                let cand = rng.below(h);
                let phase = cand as f64 / h as f64;
                let rate =
                    0.25 + 0.375 * (1.0 - (4.0 * std::f64::consts::PI * phase).cos());
                if rng.f64() < rate {
                    break cand;
                }
            };
            let tenant = rng.below(tenants as u64) as usize;
            let accel = *rng.pick(&GEN_ACCELS);
            let variant = if rng.bool(0.25) { Some(format!("{accel}_v1")) } else { None };
            events.push(ScenarioEvent {
                t_ns: t,
                tenant,
                qos: 1 + (tenant % 3) as u32,
                accel: accel.to_string(),
                variant,
                tiles: 1 + rng.below(6) as usize,
                stream: 1 + rng.below(3) as usize,
            });
        }
        Scenario::from_events(seed, events)
    }

    /// Correlated bursts: `n_bursts` tight clusters of `per_burst`
    /// arrivals each, every burst fanning over several tenants at once
    /// on one shared accelerator — the "everyone spikes together"
    /// shape placement policies hate.
    pub fn bursts(
        seed: u64,
        tenants: usize,
        n_bursts: usize,
        per_burst: usize,
        horizon_ns: u64,
    ) -> Scenario {
        let mut rng = Rng::new(seed ^ DOMAIN_BURSTS);
        let tenants = tenants.max(1);
        let h = horizon_ns.max(1);
        let width = (h / 64).max(1);
        let mut events = Vec::with_capacity(n_bursts * per_burst);
        for _ in 0..n_bursts {
            let center = rng.below(h);
            let accel = *rng.pick(&GEN_ACCELS);
            let first = rng.below(tenants as u64) as usize;
            let fan = 1 + rng.below(tenants as u64) as usize;
            for k in 0..per_burst {
                let tenant = (first + k % fan) % tenants;
                events.push(ScenarioEvent {
                    t_ns: center.saturating_add(rng.below(width)),
                    tenant,
                    qos: 1 + (tenant % 2) as u32,
                    accel: accel.to_string(),
                    variant: None,
                    tiles: 1 + rng.below(4) as usize,
                    stream: 1 + rng.below(2) as usize,
                });
            }
        }
        Scenario::from_events(seed, events)
    }

    /// Flash crowd: `baseline` arrivals spread uniformly over the
    /// horizon, then `crowd` arrivals from every tenant packed into a
    /// sub-1% window on one hot accelerator — the admission-pressure
    /// scenario the DRR/`Busy` conservation property runs at a tight
    /// `queue_cap`.
    pub fn flash_crowd(
        seed: u64,
        tenants: usize,
        baseline: usize,
        crowd: usize,
        horizon_ns: u64,
    ) -> Scenario {
        let mut rng = Rng::new(seed ^ DOMAIN_FLASH);
        let tenants = tenants.max(1);
        let h = horizon_ns.max(4);
        let mut events = Vec::with_capacity(baseline + crowd);
        for _ in 0..baseline {
            let tenant = rng.below(tenants as u64) as usize;
            let accel = *rng.pick(&GEN_ACCELS);
            events.push(ScenarioEvent {
                t_ns: rng.below(h),
                tenant,
                qos: 1,
                accel: accel.to_string(),
                variant: None,
                tiles: 1 + rng.below(4) as usize,
                stream: 1,
            });
        }
        let hot = *rng.pick(&GEN_ACCELS);
        let spike = h / 4 + rng.below((h / 2).max(1));
        let window = (h / 128).max(1);
        for k in 0..crowd {
            events.push(ScenarioEvent {
                t_ns: spike.saturating_add(rng.below(window)),
                tenant: k % tenants,
                qos: 1,
                accel: hot.to_string(),
                variant: Some(format!("{hot}_v1")),
                tiles: 1 + rng.below(2) as usize,
                stream: 1,
            });
        }
        Scenario::from_events(seed, events)
    }

    /// Heavy-tailed job sizes: uniform arrivals whose tile counts and
    /// stream widths follow bounded Pareto distributions — most jobs
    /// tiny, a deterministic few elephants.
    pub fn heavy_tailed(seed: u64, tenants: usize, jobs: usize, horizon_ns: u64) -> Scenario {
        let mut rng = Rng::new(seed ^ DOMAIN_PARETO);
        let tenants = tenants.max(1);
        let h = horizon_ns.max(1);
        let mut events = Vec::with_capacity(jobs);
        for _ in 0..jobs {
            let tenant = rng.below(tenants as u64) as usize;
            let accel = *rng.pick(&GEN_ACCELS);
            events.push(ScenarioEvent {
                t_ns: rng.below(h),
                tenant,
                qos: 1 + (tenant % 3) as u32,
                accel: accel.to_string(),
                variant: None,
                tiles: bounded_pareto(&mut rng, 1.3, 1, 32) as usize,
                stream: bounded_pareto(&mut rng, 1.5, 1, 12) as usize,
            });
        }
        Scenario::from_events(seed, events)
    }

    /// Parse a scenario spec (`fos daemon --scenario <spec>`,
    /// `FOS_SCENARIO=<spec>`): comma- or semicolon-separated
    /// `key=value` entries —
    ///
    /// - `v=1` — trace format version (optional, must be 1)
    /// - `seed=N` — generator/draw seed (default 0)
    /// - `inflight=N` — uniform per-tenant in-flight quota
    /// - `at=T@tU wW:ACCEL[/VARIANT]xTILES*STREAM` (no space; one per
    ///   arrival) — at time `T`, tenant `U` with DRR weight `W`
    ///   submits `STREAM` requests of `TILES` tiles on `ACCEL`.  `T`
    ///   is milliseconds, or exact nanoseconds with an `ns` suffix —
    ///   [`Scenario::to_spec`] emits the latter so a repro artifact
    ///   replays bit-identically.
    /// - `gen=diurnal|bursts|flash|pareto` — expand a named generator
    ///   instead of listing records, shaped by `tenants=`, `jobs=`,
    ///   `horizon=` (ms or `ns`), `bursts=`, `per=`, `base=`,
    ///   `crowd=`.  Mutually exclusive with `at=` entries.
    ///
    /// e.g. `gen=diurnal,seed=7,tenants=4,jobs=48,horizon=40` or
    /// `v=1,seed=0,at=1500000ns@t0w2:sobel/sobel_v1x4*3`.
    pub fn parse(spec: &str) -> Result<Scenario, String> {
        let mut seed = 0u64;
        let mut inflight = usize::MAX;
        let mut events: Vec<ScenarioEvent> = Vec::new();
        let mut gen: Option<String> = None;
        let (mut tenants, mut jobs, mut horizon) = (4usize, 48usize, 40_000_000u64);
        let (mut n_bursts, mut per_burst) = (4usize, 12usize);
        let (mut baseline, mut crowd) = (16usize, 32usize);
        for part in spec.split([',', ';']).filter(|p| !p.trim().is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("scenario entry {part:?} is not key=value"))?;
            let (key, value) = (key.trim(), value.trim());
            let as_usize = |v: &str| -> Result<usize, String> {
                v.parse().map_err(|_| format!("bad scenario {key} {v:?}"))
            };
            match key {
                "v" => {
                    if value != "1" {
                        return Err(format!("unsupported scenario version {value:?}"));
                    }
                }
                "seed" => {
                    seed = value.parse().map_err(|_| format!("bad seed {value:?}"))?;
                }
                "inflight" => inflight = as_usize(value)?.max(1),
                "gen" => gen = Some(value.to_string()),
                "tenants" => tenants = as_usize(value)?,
                "jobs" => jobs = as_usize(value)?,
                "horizon" => horizon = parse_time(value)?,
                "bursts" => n_bursts = as_usize(value)?,
                "per" => per_burst = as_usize(value)?,
                "base" => baseline = as_usize(value)?,
                "crowd" => crowd = as_usize(value)?,
                "at" => events.push(parse_event(value)?),
                other => return Err(format!("unknown scenario key {other:?}")),
            }
        }
        let mut sc = match gen.as_deref() {
            None => Scenario { seed, inflight: usize::MAX, events },
            Some(name) => {
                if !events.is_empty() {
                    return Err("gen= and at= entries are mutually exclusive".into());
                }
                match name {
                    "diurnal" => Scenario::diurnal(seed, tenants, jobs, horizon),
                    "bursts" => Scenario::bursts(seed, tenants, n_bursts, per_burst, horizon),
                    "flash" => Scenario::flash_crowd(seed, tenants, baseline, crowd, horizon),
                    "pareto" => Scenario::heavy_tailed(seed, tenants, jobs, horizon),
                    other => return Err(format!("unknown scenario generator {other:?}")),
                }
            }
        };
        if inflight != usize::MAX {
            sc = sc.with_inflight(inflight);
        }
        Ok(sc)
    }

    /// Render the trace back to the [`Scenario::parse`] spec format —
    /// always the *expanded* record list (a `gen=` spec renders to its
    /// events), always ns-exact, so a repro artifact replays
    /// bit-identically.
    pub fn to_spec(&self) -> String {
        let mut out = vec!["v=1".to_string(), format!("seed={}", self.seed)];
        if self.inflight != usize::MAX {
            out.push(format!("inflight={}", self.inflight));
        }
        for e in &self.events {
            let variant =
                e.variant.as_deref().map(|v| format!("/{v}")).unwrap_or_default();
            out.push(format!(
                "at={}ns@t{}w{}:{}{}x{}*{}",
                e.t_ns, e.tenant, e.qos, e.accel, variant, e.tiles, e.stream
            ));
        }
        out.join(",")
    }

    /// Lower the trace into the harnesses' native [`Workload`]: one
    /// [`JobSpec`] per record (in record order — the arrival tie-break
    /// both DES heaps replay) plus the per-tenant QoS table (last
    /// record of a tenant wins, tenant id ascending).
    pub fn to_workload(&self) -> Workload {
        let mut w: Workload = self
            .events
            .iter()
            .map(|e| JobSpec {
                user: e.tenant,
                accel: e.accel.clone(),
                arrival: e.t_ns,
                requests: e.stream,
                tiles_per_request: e.tiles,
                pin_variant: e.variant.clone(),
            })
            .collect();
        let mut qos: BTreeMap<usize, u32> = BTreeMap::new();
        for e in &self.events {
            qos.insert(e.tenant, e.qos);
        }
        for (t, weight) in qos {
            w.set_qos(t, QosClass::new(weight, self.inflight));
        }
        w
    }
}

/// `T` in milliseconds, or exact nanoseconds with an `ns` suffix (an
/// overflowing ms value is a structured error, never a wrapped time).
fn parse_time(t: &str) -> Result<u64, String> {
    match t.strip_suffix("ns") {
        Some(ns) => ns.parse().map_err(|_| format!("bad scenario time {t:?}")),
        None => t
            .parse::<u64>()
            .ok()
            .and_then(|ms| ms.checked_mul(1_000_000))
            .ok_or_else(|| format!("bad scenario time {t:?}")),
    }
}

/// One `at=` record: `T@tU wW:ACCEL[/VARIANT]xTILES*STREAM` (no space).
fn parse_event(value: &str) -> Result<ScenarioEvent, String> {
    let bad = || format!("bad scenario record {value:?} (want T@tUwW:ACCEL[/V]xTILES*STREAM)");
    let (time, rest) = value.split_once('@').ok_or_else(bad)?;
    let (head, tail) = rest.split_once(':').ok_or_else(bad)?;
    let (tenant, weight) = head.strip_prefix('t').and_then(|h| h.split_once('w')).ok_or_else(bad)?;
    let (name, stream) = tail.rsplit_once('*').ok_or_else(bad)?;
    let (name, tiles) = name.rsplit_once('x').ok_or_else(bad)?;
    let (accel, variant) = match name.split_once('/') {
        Some((a, v)) => (a.to_string(), Some(v.to_string())),
        None => (name.to_string(), None),
    };
    let e = ScenarioEvent {
        t_ns: parse_time(time)?,
        tenant: tenant.parse().map_err(|_| bad())?,
        qos: weight.parse().map_err(|_| bad())?,
        accel,
        variant,
        tiles: tiles.parse().map_err(|_| bad())?,
        stream: stream.parse().map_err(|_| bad())?,
    };
    if e.tiles == 0 || e.stream == 0 || e.accel.is_empty() {
        return Err(bad());
    }
    Ok(e)
}

/// Bounded Pareto inverse-CDF draw in `[lo, hi]` with tail index
/// `alpha` — pure in `rng`, so identical streams replay identically.
fn bounded_pareto(rng: &mut Rng, alpha: f64, lo: u64, hi: u64) -> u64 {
    let u = rng.f64();
    let (l, h) = (lo as f64, hi as f64);
    let (la, ha) = (l.powf(alpha), h.powf(alpha));
    let x = (-(u * ha - u * la - ha) / (ha * la)).powf(-1.0 / alpha);
    (x as u64).clamp(lo, hi)
}

/// How a discrete-event harness resolves its nondeterminism points —
/// the ordering-fuzz hook consulted (identically) by [`super::simulate`],
/// [`super::simulate_cluster`] and the daemon dispatcher at three
/// sites: the processing order of an equal-timestamp event batch, the
/// boundary order of an admission ingest batch, and the exact firing
/// time of a preemption-check tick (bounded additive jitter).
///
/// [`OrderStrategy::Identity`] is a no-op at every site — today's FIFO
/// orderings, byte-identical (the golden fixtures pin this).
/// [`OrderStrategy::Seeded`] replaces each with a pure seeded
/// permutation keyed by the virtual time of the hook — a *legal
/// alternative schedule* under which all conservation invariants (and,
/// when both harnesses share the strategy, decision parity) must still
/// hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OrderStrategy {
    /// Deterministic FIFO — today's behaviour, byte-identical.
    #[default]
    Identity,
    /// Seeded permutations at every hook.
    Seeded(u64),
}

impl OrderStrategy {
    /// Parse a CLI/env spec: `identity` (or empty) | `seed=N`.
    pub fn parse(spec: &str) -> Result<OrderStrategy, String> {
        match spec.trim() {
            "" | "identity" => Ok(OrderStrategy::Identity),
            s => s
                .strip_prefix("seed=")
                .and_then(|n| n.parse().ok())
                .map(OrderStrategy::Seeded)
                .ok_or_else(|| format!("bad order strategy {s:?} (want identity or seed=N)")),
        }
    }

    pub fn to_spec(&self) -> String {
        match self {
            OrderStrategy::Identity => "identity".to_string(),
            OrderStrategy::Seeded(n) => format!("seed={n}"),
        }
    }

    pub fn is_identity(&self) -> bool {
        *self == OrderStrategy::Identity
    }

    /// The pure permutation stream for one hook firing: `None` under
    /// identity (callers skip all work).
    fn rng(&self, domain: u64, key: u64) -> Option<Rng> {
        match *self {
            OrderStrategy::Identity => None,
            OrderStrategy::Seeded(seed) => {
                let mix = domain
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .wrapping_add(key.wrapping_mul(0xBF58_476D_1CE4_E5B9));
                Some(Rng::new(seed ^ mix))
            }
        }
    }

    /// Permute one equal-timestamp event batch before processing —
    /// keyed by the batch's virtual time, so both harnesses (which
    /// drain identical batches at identical times) shuffle
    /// identically.
    pub fn permute_events<T>(&self, now: u64, batch: &mut [T]) {
        if batch.len() > 1 {
            if let Some(mut rng) = self.rng(DOMAIN_EVENTS, now) {
                shuffle(&mut rng, batch);
            }
        }
    }

    /// Permute one admission ingest batch before it reaches the
    /// scheduler — the ingest-boundary fuzz (requests admitted in the
    /// same round land in a seeded submission order).
    pub fn permute_ingest<T>(&self, now: u64, batch: &mut [T]) {
        if batch.len() > 1 {
            if let Some(mut rng) = self.rng(DOMAIN_INGEST, now) {
                shuffle(&mut rng, batch);
            }
        }
    }

    /// Jitter a preemption-check tick's firing time: identity returns
    /// `t` unchanged; seeded adds up to [`TICK_JITTER_MAX_NS`], keyed
    /// by `(board, t)` so every harness jitters the same tick the same
    /// way.  Only the heap entry moves — the core's own `next_tick`
    /// bookkeeping stays at the unjittered due time on both paths.
    pub fn jitter_tick(&self, board: usize, t: u64) -> u64 {
        let key = t ^ (board as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
        match self.rng(DOMAIN_TICK, key) {
            None => t,
            Some(mut rng) => t.saturating_add(rng.below(TICK_JITTER_MAX_NS + 1)),
        }
    }
}

/// Seeded Fisher–Yates.
fn shuffle<T>(rng: &mut Rng, xs: &mut [T]) {
    for i in (1..xs.len()).rev() {
        let j = rng.below(i as u64 + 1) as usize;
        xs.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic_and_seed_sensitive() {
        for (a, b, c) in [
            (
                Scenario::diurnal(7, 4, 64, 1_000_000),
                Scenario::diurnal(7, 4, 64, 1_000_000),
                Scenario::diurnal(8, 4, 64, 1_000_000),
            ),
            (
                Scenario::bursts(7, 4, 4, 8, 1_000_000),
                Scenario::bursts(7, 4, 4, 8, 1_000_000),
                Scenario::bursts(8, 4, 4, 8, 1_000_000),
            ),
            (
                Scenario::flash_crowd(7, 4, 16, 32, 1_000_000),
                Scenario::flash_crowd(7, 4, 16, 32, 1_000_000),
                Scenario::flash_crowd(8, 4, 16, 32, 1_000_000),
            ),
            (
                Scenario::heavy_tailed(7, 4, 64, 1_000_000),
                Scenario::heavy_tailed(7, 4, 64, 1_000_000),
                Scenario::heavy_tailed(8, 4, 64, 1_000_000),
            ),
        ] {
            assert_eq!(a, b, "same seed must generate identically");
            assert_ne!(a, c, "different seeds must differ");
            assert!(!a.is_empty());
            // Events sorted by arrival, all tenants in range, all
            // records well-formed.
            assert!(a.events().windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
            assert!(a.events().iter().all(|e| e.tenant < 4 && e.tiles > 0 && e.stream > 0));
        }
    }

    #[test]
    fn spec_roundtrip_is_ns_exact() {
        for sc in [
            Scenario::diurnal(3, 3, 32, 7_654_321),
            Scenario::heavy_tailed(5, 2, 24, 1_234_567),
            Scenario::new(9).with_inflight(4).with_event(ScenarioEvent {
                t_ns: 1_500_001, // off any ms boundary
                tenant: 2,
                qos: 3,
                accel: "sobel".into(),
                variant: Some("sobel_v1".into()),
                tiles: 4,
                stream: 3,
            }),
        ] {
            let spec = sc.to_spec();
            let back = Scenario::parse(&spec).unwrap();
            assert_eq!(back, sc, "spec {spec:?} must round-trip exactly");
            assert_eq!(back.to_spec(), spec);
        }
    }

    #[test]
    fn parse_accepts_generators_and_rejects_garbage() {
        let g = Scenario::parse("gen=diurnal,seed=7,tenants=3,jobs=16,horizon=5").unwrap();
        assert_eq!(g, Scenario::diurnal(7, 3, 16, 5_000_000));
        // A gen= spec's rendered trace re-parses to the same scenario.
        assert_eq!(Scenario::parse(&g.to_spec()).unwrap(), g);
        let f = Scenario::parse("gen=flash,seed=1,tenants=2,base=4,crowd=8,horizon=2000000ns");
        assert_eq!(f.unwrap(), Scenario::flash_crowd(1, 2, 4, 8, 2_000_000));
        assert!(Scenario::parse("").unwrap().is_empty());
        assert!(Scenario::parse("v=2").is_err());
        assert!(Scenario::parse("warp=1").is_err());
        assert!(Scenario::parse("gen=nope").is_err());
        assert!(Scenario::parse("at=nope").is_err());
        assert!(Scenario::parse("at=1@t0w1:sobelx0*1").is_err(), "zero tiles");
        assert!(Scenario::parse("at=99999999999999999@t0w1:sobelx1*1").is_err(), "ms overflow");
        assert!(Scenario::parse("gen=diurnal,at=1@t0w1:sobelx1*1").is_err(), "gen+at");
    }

    #[test]
    fn lowering_conserves_records_and_qos() {
        let sc = Scenario::diurnal(11, 5, 40, 10_000_000);
        let w = sc.to_workload();
        assert_eq!(w.jobs.len(), sc.events().len());
        assert_eq!(w.total_requests(), sc.total_requests());
        for (j, e) in w.jobs.iter().zip(sc.events()) {
            assert_eq!(j.user, e.tenant);
            assert_eq!(j.arrival, e.t_ns);
            assert_eq!(j.requests, e.stream);
            assert_eq!(j.tiles_per_request, e.tiles);
            assert_eq!(j.accel, e.accel);
        }
        // One QoS entry per distinct tenant, weights from the records.
        let tenants: std::collections::BTreeSet<usize> =
            sc.events().iter().map(|e| e.tenant).collect();
        assert_eq!(w.qos.len(), tenants.len());
        // A uniform inflight quota reaches every class.
        let capped = sc.clone().with_inflight(2).to_workload();
        assert!(capped.qos.iter().all(|(_, q)| q.max_inflight == 2));
    }

    #[test]
    fn heavy_tail_actually_has_a_tail() {
        let sc = Scenario::heavy_tailed(13, 4, 256, 1_000_000);
        let tiles: Vec<usize> = sc.events().iter().map(|e| e.tiles).collect();
        let small = tiles.iter().filter(|&&t| t <= 4).count();
        let big = tiles.iter().filter(|&&t| t >= 16).count();
        assert!(small > tiles.len() / 2, "most jobs are small: {small}/{}", tiles.len());
        assert!(big >= 1, "at least one elephant");
    }

    #[test]
    fn identity_strategy_is_a_no_op() {
        let id = OrderStrategy::default();
        assert!(id.is_identity());
        let mut xs = vec![1, 2, 3, 4, 5];
        id.permute_events(123, &mut xs);
        id.permute_ingest(123, &mut xs);
        assert_eq!(xs, vec![1, 2, 3, 4, 5]);
        assert_eq!(id.jitter_tick(0, 5_000_000), 5_000_000);
    }

    #[test]
    fn seeded_strategy_is_deterministic_and_bounded() {
        let s = OrderStrategy::Seeded(42);
        let (mut a, mut b): (Vec<u32>, Vec<u32>) = ((0..16).collect(), (0..16).collect());
        s.permute_events(777, &mut a);
        s.permute_events(777, &mut b);
        assert_eq!(a, b, "same (seed, time) must permute identically");
        let mut c: Vec<u32> = (0..16).collect();
        s.permute_events(778, &mut c);
        assert_ne!(a, c, "different times must permute differently");
        assert_ne!(a, (0..16).collect::<Vec<u32>>(), "16 elements virtually never fixed");
        // Ingest and event hooks use independent streams.
        let mut d: Vec<u32> = (0..16).collect();
        s.permute_ingest(777, &mut d);
        assert_ne!(a, d);
        // Jitter is additive and bounded.
        for b in 0..3usize {
            for t in [1u64, 5_000_000, 123_456_789] {
                let j = s.jitter_tick(b, t);
                assert!(j >= t && j <= t + TICK_JITTER_MAX_NS, "{j} vs {t}");
                assert_eq!(j, s.jitter_tick(b, t), "pure in (board, t)");
            }
        }
        assert_ne!(
            s.jitter_tick(0, 5_000_000),
            s.jitter_tick(1, 5_000_000),
            "boards jitter independently (w.h.p.)"
        );
    }

    #[test]
    fn order_strategy_spec_roundtrip() {
        for s in [OrderStrategy::Identity, OrderStrategy::Seeded(7)] {
            assert_eq!(OrderStrategy::parse(&s.to_spec()).unwrap(), s);
        }
        assert_eq!(OrderStrategy::parse("").unwrap(), OrderStrategy::Identity);
        assert!(OrderStrategy::parse("seed=x").is_err());
        assert!(OrderStrategy::parse("chaos").is_err());
    }
}
