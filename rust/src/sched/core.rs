//! # The scheduler core — one pluggable, resource-elastic brain (§4.4)
//!
//! FOS's headline claim is that a *single* resource-elastic scheduler
//! arbitrates the FPGA in time and space for every consumer.  This
//! module is that scheduler: a pure, side-effect-free state machine
//! ([`SchedCore`]) shared by the offline discrete-event simulator
//! ([`super::simulate`]) and the live multi-tenant daemon
//! ([`crate::daemon::Daemon`]).  Both harnesses feed the same three
//! inputs — request arrivals ([`SchedCore::submit`]), completions
//! ([`SchedCore::complete`]) and dispatch rounds
//! ([`SchedCore::next_decision`]) — and turn the resulting
//! [`Decision`]s into virtual-time trace events (simulator) or real
//! partial reconfigurations and PJRT executions (daemon).
//!
//! ## The `SchedPolicy` trait
//!
//! Placement strategy is pluggable.  A policy sees a read-only
//! [`RegionMap`] (what is loaded/busy where), the shared [`CostModel`]
//! (DMA + compute + reconfiguration latencies) and one [`PlaceReq`]
//! (the head-of-queue request of the user whose round-robin turn it
//! is), and answers with a [`Placement`] — *which anchor region, which
//! implementation variant, and whether a partial reconfiguration is
//! needed* — or `None` to skip the user this round (e.g. to wait for a
//! busy instance instead of paying a reconfiguration).
//!
//! Four seed implementations ship:
//!
//! - [`Elastic`] — the paper's policy: **reuse** an idle instance
//!   without reconfiguring, otherwise **replace** free capacity with
//!   the variant minimising reconfig + backlog drain (replication-
//!   aware), growing to **multi-region spans** when a single tenant is
//!   active, and **skipping** when a busy instance makes waiting
//!   cheaper than reconfiguring (§4.4.3's reconfiguration avoidance).
//!   [`Elastic::preemptive`] additionally checkpoints a replicated
//!   tenant's span when another tenant is starved.
//! - [`Fixed`] — the baseline: one static 1-region module per user,
//!   run-to-completion.
//! - [`Quantum`] — round-robin time-slicing: FOS's cooperative §4.4.3
//!   scheduling made preemptive.  A request that has held its module
//!   past the quantum while another user is starved is checkpointed
//!   and its remainder requeued.
//! - [`FairShare`] — weighted tenant fair share (THEMIS-style): spans
//!   per tenant capped at the tenant's weighted share of the fabric,
//!   fully starved tenants preempt the biggest holder.  Reads the
//!   tenant fields ([`PlaceReq::tenant_running`], [`PlaceReq::weight`],
//!   [`PlaceReq::active_weight`]) the core threads through every
//!   request.
//!
//! ## Preemption (time-domain elasticity)
//!
//! FOS arbitrates the fabric "in both time and spatial domain"; the
//! spatial half is the placement logic above, the time half is
//! **preemptive checkpoint/restore**.  When a policy cannot place a
//! request it may name a running victim instead
//! ([`SchedPolicy::preempt`]).  The core then
//!
//! 1. computes the victim's progress from the running record the
//!    harness registered ([`SchedCore::mark_running`]) — tiles
//!    completed vs tiles total at the current virtual time,
//! 2. stores a [`Checkpoint`] (accelerator, variant, progress) under a
//!    fresh checkpoint id,
//! 3. requeues the *remaining* tiles at the front of the victim's
//!    queue, pinned to the checkpointed variant, and
//! 4. emits a [`DecisionKind::Preempt`] decision so both harnesses
//!    mirror the effect (the simulator cancels the victim's completion
//!    event; the daemon runs the completed slice for real and snapshots
//!    the register file through `Cynq::checkpoint_accelerator`).
//!
//! The requeued remainder is dispatched later as a
//! [`DecisionKind::Resume`] decision whose service time carries the
//! checkpoint + restore overhead ([`CostModel::checkpoint_ns`] /
//! [`CostModel::restore_ns`]).  Harnesses re-run a scheduling round
//! every [`PREEMPT_TICK_NS`] of virtual time while users are starved
//! and work is running, so a quantum expiring mid-span is observed.
//!
//! ## Adding a new policy
//!
//! Implement [`SchedPolicy`] (state lives in your struct — see
//! [`Fixed`]'s `home` map), register it with
//! [`SchedCore::register_policy`], and route users to it with
//! [`SchedCore::set_user_policy`].  A THEMIS-style fairness policy or
//! a preemption-aware policy is a new `impl`, not a fork of two code
//! paths; the daemon protocol exposes the same knob per tenant
//! (`FpgaRpc::set_policy`).
//!
//! ## Decision bookkeeping
//!
//! The core owns the shared counters ([`SchedCounters`]: reconfigs,
//! reuses, skips, replications) and an ordered decision log, so the
//! simulator's `SimResult` and the daemon's `DaemonStats` report from
//! the *same* source — the parity test in `tests/sched_parity.rs`
//! drives one trace through both and asserts identical sequences.
//! Replacement victims are picked through an ordered LRU index
//! (`BTreeSet<(tick, region)>`), not a linear scan of insertion order.
//!
//! ## Hot path & memory discipline
//!
//! A scheduling round is allocation-free in the steady state (see
//! `sched/ARCHITECTURE.md`, *Hot path & memory discipline*):
//!
//! - accelerator/variant names are interned once per core into integer
//!   [`Sym`]s by a [`SymbolTable`] derived deterministically from the
//!   catalog, so [`Request`]/[`Decision`]/[`RunningSnap`]/[`Checkpoint`]
//!   are `Copy` and every queue push, log append and tail query is a
//!   memcpy — names are resolved back to `&str` only at the RPC/trace
//!   boundary ([`SchedCore::resolve`]);
//! - per-user queue statistics, pending/backlog/stealable totals and
//!   the non-empty-user index are maintained incrementally on every
//!   enqueue/dequeue, so the round-robin user scan and the `PlaceReq`
//!   fields cost `O(log users)` instead of a full scan;
//! - round-scoped buffers (`scratch_snaps`, `scratch_tenants`) and the
//!   round-stamped skip marks (`skip_round`) live on the core and are
//!   reused, never reallocated per round;
//! - [`RegionMap`] keeps a residency index (accelerator sym → anchor
//!   set) and a blank-slot index coherent with every `loaded`/`tail_of`
//!   mutation, so `idle_resident`/`find_free_span`/replication checks
//!   stop walking every region.

use crate::accel::{Accelerator, Catalog};
use crate::memsim::{config_for, DdrModel};
use crate::reconfig::FpgaManager;
use crate::shell::Shell;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Virtual period at which harnesses re-run a scheduling round while at
/// least one user is starved (deferred) and work is running — the
/// cadence at which expired quanta are observed.  Both the simulator
/// and the daemon schedule these ticks with identical rules, so the
/// decision sequences stay in lockstep.
pub const PREEMPT_TICK_NS: u64 = 5_000_000;

/// Built-in scheduling policy selector (the daemon protocol's knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// FOS: replication + replacement + reuse + time-mux (§4.4.3).
    Elastic,
    /// Baseline: one fixed 1-region module per user, run-to-completion.
    Fixed,
    /// Round-robin time-slicing with checkpoint/restore preemption.
    Quantum,
    /// [`Policy::Elastic`] plus starvation-driven preemption of
    /// replicated spans.
    ElasticPreempt,
    /// Weighted tenant fair share: concurrent spans capped at each
    /// tenant's weighted share of the fabric, starved tenants preempt
    /// the biggest holder ([`FairShare`]).
    FairShare,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Elastic => "elastic",
            Policy::Fixed => "fixed",
            Policy::Quantum => "quantum",
            Policy::ElasticPreempt => "elastic-pre",
            Policy::FairShare => "fair",
        }
    }

}

/// An interned accelerator or variant name.
///
/// Syms are assigned by [`SymbolTable::from_catalog`] in a
/// deterministic order (catalog accelerators are name-sorted, variants
/// region-sorted), so every holder of the same catalog — each cluster
/// shard, the daemon, a test harness — derives the *identical* mapping
/// and syms can cross [`SchedCore`] boundaries without translation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Sym(u32);

impl Sym {
    /// Dense table index of this sym.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Interner mapping accelerator/variant names to dense [`Sym`] ids.
///
/// Built once per core from the catalog; the scheduler hot path deals
/// exclusively in syms and resolves back to `&str` only at the
/// RPC/trace boundary.
#[derive(Debug, Clone, Default)]
pub struct SymbolTable {
    names: Vec<String>,
    index: BTreeMap<String, Sym>,
}

impl SymbolTable {
    pub fn new() -> SymbolTable {
        SymbolTable::default()
    }

    /// The canonical table for a catalog: every accelerator name, then
    /// each of its variant names, in catalog order.  Catalog order is
    /// itself deterministic (accelerators name-sorted at load, variants
    /// region-sorted), so two tables built from equal catalogs are
    /// equal.
    pub fn from_catalog(catalog: &Catalog) -> SymbolTable {
        let mut t = SymbolTable::new();
        for a in &catalog.accelerators {
            t.intern(&a.name);
            for v in &a.variants {
                t.intern(&v.name);
            }
        }
        t
    }

    /// Intern `name`, returning its (possibly pre-existing) sym.
    pub fn intern(&mut self, name: &str) -> Sym {
        if let Some(&s) = self.index.get(name) {
            return s;
        }
        let s = Sym(self.names.len() as u32);
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), s);
        s
    }

    /// Sym of an already-interned name.
    pub fn lookup(&self, name: &str) -> Option<Sym> {
        self.index.get(name).copied()
    }

    /// Name of `sym` (a stable placeholder for out-of-table syms, so
    /// diagnostics never panic on a buggy policy's fabricated sym).
    pub fn resolve(&self, sym: Sym) -> &str {
        self.names
            .get(sym.index())
            .map(String::as_str)
            .unwrap_or("<unknown-sym>")
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

/// What a PR region currently holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoadedModule {
    pub accel: Sym,
    pub variant: Sym,
    /// Adjacent regions the variant spans (anchor included).
    pub span: usize,
}

/// Scheduler-visible state of one PR region.
#[derive(Debug, Clone, Copy)]
pub struct Region {
    /// The module anchored here (tails carry `None` + `tail_of`).
    pub loaded: Option<LoadedModule>,
    /// Anchor index if this slot is the tail of a combined span.
    pub tail_of: Option<usize>,
    /// An acceleration request is running on the module anchored here.
    pub busy: bool,
    /// LRU tick of the last placement touching this region.
    last_used: u64,
}

/// One queued acceleration request (the §4.4.2 data-parallel unit).
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub user: usize,
    /// QoS identity the request is accounted to (several users —
    /// daemon connections — may share one tenant; defaults to `user`).
    pub tenant: usize,
    /// Harness-owned token (simulator: workload job index; daemon:
    /// monotonic job id) — echoed back in the [`Decision`].
    pub job: u64,
    pub accel: Sym,
    /// Work items batched in this request.
    pub tiles: usize,
    /// Pin a specific implementation variant (None = policy's choice).
    pub pin: Option<Sym>,
    /// `Some(checkpoint id)`: this request is the requeued remainder of
    /// a preempted dispatch and must restore that checkpoint.
    pub resume: Option<u64>,
}

/// What a [`Decision`] asks the harness to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionKind {
    /// Fresh dispatch of a queued request.
    Run,
    /// Dispatch of a preempted request's remainder: restore the
    /// checkpoint named by [`Decision::ckpt`], then run the remaining
    /// tiles.
    Resume,
    /// Checkpoint the request running at [`Decision::anchor`] *now*:
    /// its completion is cancelled, the span is idle again, and the
    /// remaining [`Decision::tiles`] re-enter the victim's queue.
    Preempt,
}

/// A committed scheduling decision: run `user`'s head request on the
/// module (re)configured at `anchor..anchor+span` — or, for
/// [`DecisionKind::Preempt`], checkpoint the request running there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    pub user: usize,
    /// Tenant the dispatched request is accounted to.
    pub tenant: usize,
    pub job: u64,
    pub accel: Sym,
    pub variant: Sym,
    pub anchor: usize,
    pub span: usize,
    /// Work items this decision covers. For `Preempt` decisions: the
    /// tiles *remaining* (requeued); the victim completed
    /// `original - tiles` of its work.
    pub tiles: usize,
    /// `true`: a partial reconfiguration was paid; `false`: reuse.
    pub reconfigure: bool,
    /// Another instance of the same accelerator is resident elsewhere
    /// on the fabric after this placement (replication, Fig 20).
    pub replicated: bool,
    /// What the harness must do with this decision.
    pub kind: DecisionKind,
    /// Checkpoint id: created by a `Preempt`, consumed by the matching
    /// `Resume` (the daemon keys its register-file snapshots by it).
    /// Failover `Preempt`s emitted by a board-down drain carry `None` —
    /// the *target* shard assigns the id when it adopts the checkpoint.
    pub ckpt: Option<u64>,
    /// The dispatched request's variant pin, carried so a failed
    /// placement can be rolled back into an identical [`Request`]
    /// ([`SchedCore::rollback_failed_dispatch`]).
    pub pin: Option<Sym>,
}

/// Counters both the simulator and the daemon report from.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SchedCounters {
    /// Placements that paid a partial reconfiguration.
    pub reconfigs: u64,
    /// Placements that reused a resident idle instance.
    pub reuses: u64,
    /// Rounds where a user was deferred (reconfiguration avoidance,
    /// busy fixed home, no placeable capacity).
    pub skips: u64,
    /// Reconfigurations that created an *additional* instance of an
    /// already-resident accelerator (replication events).
    pub replications: u64,
    /// Running requests checkpointed and requeued ([`DecisionKind::Preempt`]).
    pub preemptions: u64,
    /// Requeued remainders re-dispatched ([`DecisionKind::Resume`]).
    pub resumes: u64,
}

/// Virtual-time latency model shared by the simulator and the daemon —
/// DMA from the memsim DDR model, compute from the manifest cycle
/// models, reconfiguration from the PCAP model.
pub struct CostModel {
    ddr: DdrModel,
    /// Bytes of a single-region partial bitstream on this shell.
    region_bytes: usize,
}

impl CostModel {
    pub fn new(shell: &Shell) -> CostModel {
        use crate::bitstream::{region_frames, FRAME_WORDS};
        let dev = &shell.floorplan.device;
        let region_bytes = region_frames(dev, &shell.floorplan.regions[0]).len() * FRAME_WORDS * 4;
        CostModel { ddr: DdrModel::new(config_for(shell.board)), region_bytes }
    }

    /// Partial-bitstream load latency for a `span`-region module (ns).
    pub fn reconfig_ns(&self, span: usize) -> u64 {
        FpgaManager::latency_for(self.region_bytes * span, true).as_nanos() as u64
    }

    /// Per-tile DMA (in + out) under `concurrent` other busy modules.
    pub fn dma_ns(&self, accel: &Accelerator, concurrent: usize) -> f64 {
        self.ddr.transfer_ns(accel.bytes_in, concurrent)
            + self.ddr.transfer_ns(accel.bytes_out, concurrent)
    }

    /// Per-tile service time: DMA + modelled compute.
    pub fn per_tile_ns(
        &self,
        accel: &Accelerator,
        variant: &crate::accel::Variant,
        concurrent: usize,
    ) -> f64 {
        self.dma_ns(accel, concurrent) + variant.compute_ns()
    }

    /// [`CostModel::per_tile_ns`] under weighted memory-bandwidth
    /// partitioning (see [`DdrModel::transfer_ns_partitioned`]): the
    /// DMA legs run at the dispatching tenant's QoS share of the
    /// contended bandwidth instead of the per-master equal split.
    #[allow(clippy::too_many_arguments)]
    pub fn per_tile_ns_partitioned(
        &self,
        accel: &Accelerator,
        variant: &crate::accel::Variant,
        weight: u32,
        active_weight: u32,
        tenant_masters: usize,
        concurrent: usize,
    ) -> f64 {
        self.ddr.transfer_ns_partitioned(
            accel.bytes_in,
            weight,
            active_weight,
            tenant_masters,
            concurrent,
        ) + self.ddr.transfer_ns_partitioned(
            accel.bytes_out,
            weight,
            active_weight,
            tenant_masters,
            concurrent,
        ) + variant.compute_ns()
    }

    /// Context save of a running `span`-region module: PCAP readback of
    /// its register file + progress counters and in-flight state drain.
    /// Modelled as a quarter of the span's partial-bitstream load.
    pub fn checkpoint_ns(&self, span: usize) -> u64 {
        self.reconfig_ns(span) / 4
    }

    /// Context restore before re-arming a checkpointed module
    /// (symmetric to [`CostModel::checkpoint_ns`]).
    pub fn restore_ns(&self, span: usize) -> u64 {
        self.reconfig_ns(span) / 4
    }
}

/// Read-only view of one running request, handed to
/// [`SchedPolicy::preempt`] so policies can pick a victim.  Registered
/// by the harness through [`SchedCore::mark_running`].
#[derive(Debug, Clone, Copy)]
pub struct RunningSnap {
    pub user: usize,
    /// Tenant of the dispatched request (fair-share victim selection).
    pub tenant: usize,
    pub job: u64,
    pub accel: Sym,
    pub variant: Sym,
    pub anchor: usize,
    pub span: usize,
    /// Tiles this dispatch covers.
    pub tiles: usize,
    /// Virtual dispatch time.
    pub start: u64,
    /// Virtual completion time the harness scheduled.
    pub end: u64,
    /// Leading non-compute part of `[start, end)`: reconfiguration
    /// and/or restore overhead before the first tile starts.
    pub setup: u64,
    /// This dispatch is itself the remainder of an earlier preemption.
    pub resumed: bool,
    /// The checkpoint a `Resume` dispatch consumed (its progress record
    /// is parked in the consumed-checkpoint stash until completion, so
    /// a failed or failed-over dispatch can reconstruct it).
    pub ckpt: Option<u64>,
}

/// Progress record of a preempted request, stored until its remainder
/// is resumed.  The scheduler-core half of checkpoint/restore: the
/// daemon pairs it with a `Cynq::checkpoint_accelerator` register-file
/// snapshot keyed by the same checkpoint id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Checkpoint {
    pub accel: Sym,
    pub variant: Sym,
    /// Anchor the victim was running at (a restore may relocate).
    pub anchor: usize,
    pub span: usize,
    /// Tiles completed before the preemption.
    pub tiles_done: usize,
    /// Tiles of the original dispatch.
    pub tiles_total: usize,
}

/// One running dispatch drained off a failed board
/// ([`SchedCore::drain_running_for_failover`]): the `Preempt` decision
/// logged for it, the remainder request the cluster layer migrates,
/// the progress record the target shard adopts (when any tiles
/// completed), and the virtual work the failure destroyed.
#[derive(Debug, Clone, Copy)]
pub struct FailoverDrain {
    pub decision: Decision,
    pub request: Request,
    pub checkpoint: Option<Checkpoint>,
    /// Virtual ns the failed dispatch burned that the checkpoint does
    /// NOT preserve (setup overhead + the in-progress tile).
    pub lost_ns: u64,
    /// Tiles the checkpoint preserves (0 = plain re-run).
    pub done: usize,
    /// Anchor the dispatch was running at on the failed board.
    pub anchor: usize,
}

/// Read-only region state handed to policies, with the span queries the
/// seed policies need and the ordered-LRU replacement index.
///
/// Two secondary indexes keep the placement path from walking every
/// region (see `sched/ARCHITECTURE.md`, *Hot path & memory
/// discipline*).  Every `loaded`/`tail_of` mutation is funneled through
/// [`RegionMap::set_slot`], which maintains both:
///
/// - `by_accel`: accelerator sym → anchors where an instance is
///   resident (entries always have `tail_of == None`; `busy` is
///   checked per-query because it changes without residency changing);
/// - `blank`: slots with neither a module nor tail membership — the
///   candidates for a destroy-nothing blank-span placement.
pub struct RegionMap {
    regions: Vec<Region>,
    /// Max combinable span anchored at each region (floorplan).
    max_span: Vec<usize>,
    /// Replacement order: `(last_used tick, region)` — oldest first.
    lru: BTreeSet<(u64, usize)>,
    clock: u64,
    /// Residency index: accelerator sym → anchors holding an instance.
    by_accel: BTreeMap<Sym, BTreeSet<usize>>,
    /// Slots with `loaded == None && tail_of == None`.
    blank: BTreeSet<usize>,
}

impl RegionMap {
    fn new(shell: &Shell) -> RegionMap {
        let n = shell.region_count();
        let max_span = (0..n)
            .map(|a| {
                (1..=n - a)
                    .take_while(|&k| shell.floorplan.combinable(a, k))
                    .last()
                    .unwrap_or(0)
            })
            .collect();
        RegionMap {
            regions: (0..n)
                .map(|_| Region { loaded: None, tail_of: None, busy: false, last_used: 0 })
                .collect(),
            max_span,
            lru: (0..n).map(|i| (0u64, i)).collect(),
            clock: 0,
            by_accel: BTreeMap::new(),
            blank: (0..n).collect(),
        }
    }

    /// The single mutation point for a slot's residency state; keeps
    /// `by_accel` and `blank` coherent with `loaded`/`tail_of`.
    fn set_slot(&mut self, i: usize, loaded: Option<LoadedModule>, tail_of: Option<usize>) {
        if let Some(old) = self.regions[i].loaded {
            if let Some(set) = self.by_accel.get_mut(&old.accel) {
                set.remove(&i);
                if set.is_empty() {
                    self.by_accel.remove(&old.accel);
                }
            }
        }
        self.regions[i].loaded = loaded;
        self.regions[i].tail_of = tail_of;
        if let Some(l) = loaded {
            self.by_accel.entry(l.accel).or_default().insert(i);
        }
        if loaded.is_none() && tail_of.is_none() {
            self.blank.insert(i);
        } else {
            self.blank.remove(&i);
        }
    }

    pub fn len(&self) -> usize {
        self.regions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.regions.is_empty()
    }

    pub fn get(&self, i: usize) -> &Region {
        &self.regions[i]
    }

    pub fn iter(&self) -> impl Iterator<Item = &Region> {
        self.regions.iter()
    }

    /// Anchors with a request currently running.
    pub fn busy_anchors(&self) -> usize {
        self.regions.iter().filter(|r| r.busy).count()
    }

    /// Slots that could take a placement now (non-busy, non-tail) —
    /// the replication head-room the elastic score spreads over.
    pub fn free_slots(&self) -> usize {
        self.regions.iter().filter(|r| !r.busy && r.tail_of.is_none()).count()
    }

    /// Anchors where an instance of `accel` is resident, ascending
    /// (busy or not) — the residency index behind every reuse scan.
    pub fn resident(&self, accel: Sym) -> impl Iterator<Item = usize> + '_ {
        self.by_accel.get(&accel).into_iter().flatten().copied()
    }

    /// An instance of `accel` is configured somewhere on the fabric.
    pub fn has_resident(&self, accel: Sym) -> bool {
        self.by_accel.get(&accel).is_some_and(|s| !s.is_empty())
    }

    /// An instance of `accel` is resident at some anchor other than
    /// `anchor` (the replication signal, Fig 20).
    pub fn replicated_elsewhere(&self, accel: Sym, anchor: usize) -> bool {
        self.by_accel
            .get(&accel)
            .is_some_and(|s| s.iter().any(|&i| i != anchor))
    }

    /// Anchor of an idle resident instance of exactly (`accel`,
    /// `variant`), if one is configured — the shared reuse scan of the
    /// fixed-variant policies ([`Quantum`], [`FairShare`]).  Walks only
    /// the residency index, not every region.
    pub fn idle_resident(&self, accel: Sym, variant: Sym) -> Option<usize> {
        self.resident(accel).find(|&i| {
            let r = &self.regions[i];
            if r.busy {
                return false;
            }
            match r.loaded {
                Some(l) => l.variant == variant && self.span_idle(i, l.span),
                None => false,
            }
        })
    }

    /// `span` adjacent regions anchored at `anchor` are idle and form
    /// exactly that module's combined slot.
    pub fn span_idle(&self, anchor: usize, span: usize) -> bool {
        if anchor + span > self.regions.len() {
            return false;
        }
        !self.regions[anchor..anchor + span].iter().any(|r| r.busy)
            && self.regions[anchor + 1..anchor + span]
                .iter()
                .all(|r| r.tail_of == Some(anchor))
    }

    fn placeable(&self, anchor: usize, span: usize) -> bool {
        self.max_span.get(anchor).is_some_and(|&m| m >= span)
            && (anchor..anchor + span).all(|r| {
                !self.regions[r].busy
                    // A tail slot may be cannibalised only with its anchor.
                    && self.regions[r].tail_of.map(|t| t >= anchor).unwrap_or(true)
            })
    }

    /// Anchor of `span` adjacent idle regions for a new load.  Blank
    /// spans win first (nothing reusable is destroyed); otherwise the
    /// LRU index picks the least-recently-touched victim anchor.  The
    /// LRU scan is exhaustive — every region always has exactly one
    /// `(tick, region)` entry — so no further fallback is needed, and
    /// `placeable`'s combinable check already implies the span fits
    /// inside the fabric.
    ///
    /// The blank-first pass draws candidate anchors from the blank-slot
    /// index instead of scanning every region: a winning anchor is
    /// necessarily blank itself (its `loaded` must be `None` and a tail
    /// always points *backwards*, so `placeable` rules out tail
    /// membership), hence the blank set — iterated ascending — yields
    /// exactly the original first-fit anchor.
    pub fn find_free_span(&self, span: usize) -> Option<usize> {
        if span == 0 || span > self.regions.len() {
            return None;
        }
        if let Some(a) = self.blank.iter().copied().find(|&a| {
            self.placeable(a, span)
                && (a..a + span).all(|r| self.regions[r].loaded.is_none())
        }) {
            return Some(a);
        }
        self.lru
            .iter()
            .find(|&&(_, a)| self.placeable(a, span))
            .map(|&(_, a)| a)
    }

    fn touch(&mut self, region: usize) {
        self.clock += 1;
        let r = &mut self.regions[region];
        self.lru.remove(&(r.last_used, region));
        r.last_used = self.clock;
        self.lru.insert((r.last_used, region));
    }

    /// Detach any span structure overlapping `[anchor, anchor+span)` —
    /// a cannibalised tail destroys the module anchored before it.
    fn clear_span(&mut self, anchor: usize, span: usize) {
        for r in anchor..anchor + span {
            if let Some(t) = self.regions[r].tail_of {
                let keep_tail = self.regions[t].tail_of;
                self.set_slot(t, None, keep_tail);
            }
            self.set_slot(r, None, None);
        }
        for r in anchor + span..self.regions.len() {
            if self.regions[r].tail_of.map(|t| t < anchor + span).unwrap_or(false) {
                self.set_slot(r, None, None);
            }
        }
    }

    /// Configure `module` at `anchor..anchor+span`, cannibalising any
    /// overlapping spans first.
    fn install(&mut self, anchor: usize, span: usize, module: LoadedModule) {
        self.clear_span(anchor, span);
        self.set_slot(anchor, Some(module), None);
        for r in anchor + 1..anchor + span {
            self.set_slot(r, None, Some(anchor));
        }
    }

    /// Forget the module anchored at `anchor` and its tail membership
    /// (`busy` is deliberately untouched — see [`SchedCore::evict`]).
    fn evict_anchor(&mut self, anchor: usize) {
        let span = self.regions[anchor].loaded.map(|l| l.span).unwrap_or(1);
        let keep_tail = self.regions[anchor].tail_of;
        self.set_slot(anchor, None, keep_tail);
        for r in anchor + 1..(anchor + span).min(self.regions.len()) {
            if self.regions[r].tail_of == Some(anchor) {
                self.set_slot(r, None, None);
            }
        }
    }

    /// Forget every module and mark every region idle (board reset).
    fn clear_all(&mut self) {
        for i in 0..self.regions.len() {
            self.set_slot(i, None, None);
            self.regions[i].busy = false;
        }
    }
}

/// The head-of-queue request a policy is asked to place.
pub struct PlaceReq<'a> {
    pub user: usize,
    /// Tenant the request is accounted to (defaults to `user`).
    pub tenant: usize,
    pub accel: &'a Accelerator,
    /// Interned sym of `accel`'s name (what [`RegionMap::resident`]
    /// and a [`Placement`] are keyed by).
    pub accel_sym: Sym,
    /// Interned syms of `accel.variants`, index-parallel to them.
    pub variant_syms: &'a [Sym],
    pub pin: Option<Sym>,
    /// Tiles queued by this user (head request included).
    pub backlog_tiles: usize,
    /// Users with pending work (contention signal for span growth).
    pub active_users: usize,
    /// Regions currently held by this tenant's running dispatches (sum
    /// of the running records' spans) — the fair-share signal, in the
    /// same unit as the fabric size so multi-region variants count
    /// their full footprint.
    pub tenant_running: usize,
    /// This tenant's QoS weight ([`SchedCore::set_tenant_weight`];
    /// default 1).
    pub weight: u32,
    /// Sum of the weights of every *active* tenant (pending work or a
    /// running dispatch, this one included) — the fair-share divisor.
    pub active_weight: u32,
}

impl PlaceReq<'_> {
    /// The variant of `accel` that `sym` names, if any (the sym-keyed
    /// counterpart of `Accelerator::variant`; variant lists hold 1–3
    /// entries, so the position scan is effectively constant).
    pub fn variant_of(&self, sym: Sym) -> Option<&crate::accel::Variant> {
        self.variant_syms
            .iter()
            .position(|&s| s == sym)
            .map(|i| &self.accel.variants[i])
    }
}

/// A policy's answer: where and what to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    pub anchor: usize,
    pub variant: Sym,
    /// `false` = reuse the resident instance at `anchor` as-is.
    pub reconfigure: bool,
}

/// A pluggable placement strategy (see the module docs for the
/// contract and the seed implementations).
pub trait SchedPolicy: Send {
    /// Stable identifier — the daemon protocol routes tenants by it.
    fn name(&self) -> &'static str;

    /// Place `req`, or `None` to defer the user for this round.
    fn place(&mut self, regions: &RegionMap, costs: &CostModel, req: &PlaceReq)
        -> Option<Placement>;

    /// `true` when this policy may ever answer [`SchedPolicy::preempt`]
    /// with a victim.  Harnesses only schedule [`PREEMPT_TICK_NS`]
    /// re-check rounds when a *preemption-capable* policy deferred a
    /// user, so run-to-completion policies keep the seed's exact event
    /// cadence (and zero tick overhead).  Default: `false`.
    fn can_preempt(&self) -> bool {
        false
    }

    /// Consulted when [`SchedPolicy::place`] returned `None`: name the
    /// anchor of a running request to checkpoint instead of deferring
    /// `req`'s user, or `None` to accept the deferral.  `now` is the
    /// current virtual time; `running` lists every in-flight dispatch
    /// in anchor order.  Default: never preempt (run-to-completion).
    fn preempt(
        &mut self,
        _regions: &RegionMap,
        _costs: &CostModel,
        _running: &[RunningSnap],
        _req: &PlaceReq,
        _now: u64,
    ) -> Option<usize> {
        None
    }

    /// `user`'s slot was retired ([`SchedCore::retire_user`]): drop any
    /// per-user state so a recycled slot starts clean. Default: none.
    fn retire(&mut self, _user: usize) {}
}

/// FOS resource-elastic placement: reuse > replace-with-best-scoring >
/// wait-for-busy-instance (§4.4.3).  With
/// [`Elastic::preemptive`], a starved tenant may additionally
/// checkpoint one span of a tenant running replicated instances —
/// trading a little of one user's parallelism for another user's
/// liveness (the higher-value placement of the two).
#[derive(Debug, Default)]
pub struct Elastic {
    /// Starvation-driven preemption enabled (the "elastic-pre" seed).
    preemptive: bool,
}

impl Elastic {
    /// The preemptive flavour, registered as `"elastic-pre"`.
    pub fn preemptive() -> Elastic {
        Elastic { preemptive: true }
    }
}

impl SchedPolicy for Elastic {
    fn name(&self) -> &'static str {
        if self.preemptive {
            "elastic-pre"
        } else {
            "elastic"
        }
    }

    fn can_preempt(&self) -> bool {
        self.preemptive
    }

    fn preempt(
        &mut self,
        _regions: &RegionMap,
        costs: &CostModel,
        running: &[RunningSnap],
        req: &PlaceReq,
        now: u64,
    ) -> Option<usize> {
        if !self.preemptive {
            return None;
        }
        // Only a genuinely starved tenant (nothing running anywhere)
        // may preempt, and only from a tenant holding >= 2 spans —
        // rebalancing replicated parallelism, never taking a user's
        // last module.
        if running.iter().any(|r| r.user == req.user) {
            return None;
        }
        let mut best: Option<(usize, u64, usize)> = None; // (share, elapsed, anchor)
        for r in running {
            if r.user == req.user {
                continue;
            }
            let share = running.iter().filter(|x| x.user == r.user).count();
            if share < 2 {
                continue;
            }
            let elapsed = now.saturating_sub(r.start);
            if elapsed == 0 {
                continue; // placed this very round
            }
            // Worth splitting only when the remaining work dwarfs the
            // checkpoint + restore + eventual re-reconfiguration bill.
            let remaining = r.end.saturating_sub(now);
            let overhead =
                costs.checkpoint_ns(r.span) + costs.restore_ns(r.span) + costs.reconfig_ns(1);
            if remaining <= 2 * overhead {
                continue;
            }
            if best.map(|(s, e, _)| (share, elapsed) > (s, e)).unwrap_or(true) {
                best = Some((share, elapsed, r.anchor));
            }
        }
        best.map(|(_, _, a)| a)
    }

    fn place(
        &mut self,
        regions: &RegionMap,
        costs: &CostModel,
        req: &PlaceReq,
    ) -> Option<Placement> {
        // 1. Reuse an idle region already configured with this
        //    accelerator (prefer the biggest loaded variant — it's
        //    fastest). Pinned jobs reuse only their pinned variant.
        //    Walks the residency index, not every region.
        let mut best_reuse: Option<(usize, usize, Sym)> = None; // (anchor, span, variant)
        for i in regions.resident(req.accel_sym) {
            let r = regions.get(i);
            if r.busy || r.tail_of.is_some() {
                continue;
            }
            if let Some(l) = r.loaded {
                if req.pin.map(|p| p == l.variant).unwrap_or(true)
                    && regions.span_idle(i, l.span)
                    && best_reuse.map(|(_, s, _)| l.span > s).unwrap_or(true)
                {
                    best_reuse = Some((i, l.span, l.variant));
                }
            }
        }
        if let Some((anchor, _, variant)) = best_reuse {
            return Some(Placement { anchor, variant, reconfigure: false });
        }

        // 2. Reconfigure free capacity. Multi-region variants only when
        //    a single tenant is active (the paper grows a lone user's
        //    share; under contention every user gets 1-region modules).
        //    Among the variants that fit, pick the one minimising
        //    reconfig + backlog x per-tile / replicas — bigger is NOT
        //    always better when the job cannot amortise the larger
        //    partial bitstream.
        let dma_est_ns = costs.dma_ns(req.accel, 0);
        let placement = if let Some(p) = req.pin {
            let v = req.variant_of(p)?;
            let anchor = regions.find_free_span(v.regions)?;
            Placement { anchor, variant: p, reconfigure: true }
        } else {
            let span_cap = if req.active_users <= 1 { regions.len() } else { 1 };
            let free_now = regions.free_slots().max(1);
            let mut best: Option<(u64, usize, Sym)> = None;
            for (vi, v) in req.accel.variants.iter().enumerate() {
                if v.regions > span_cap {
                    continue;
                }
                if let Some(anchor) = regions.find_free_span(v.regions) {
                    // Throughput-aware score: assume the backlog will
                    // spread over as many replicas of this variant as
                    // fit in the currently free capacity, each paying
                    // its own reconfiguration.
                    let replicas = (free_now / v.regions).max(1) as f64;
                    let drain =
                        req.backlog_tiles as f64 * (v.compute_ns() + dma_est_ns) / replicas;
                    let score = costs.reconfig_ns(v.regions) + drain as u64;
                    if best.map(|(s, _, _)| score < s).unwrap_or(true) {
                        best = Some((score, anchor, req.variant_syms[vi]));
                    }
                }
            }
            let (_, anchor, variant) = best?;
            Placement { anchor, variant, reconfigure: true }
        };

        // 3. Reconfiguration avoidance (§4.4.3: "the scheduler avoids
        //    partial reconfiguration and reuses an accelerator if it is
        //    already available on-chip"): if an instance of this
        //    accelerator is loaded but busy, pay a reconfiguration only
        //    when the user's backlog amortises it — otherwise wait for
        //    the busy instance to free up.
        if placement.reconfigure {
            let instance_busy =
                regions.resident(req.accel_sym).any(|i| regions.get(i).busy);
            if instance_busy {
                let v = req
                    .variant_of(placement.variant)
                    .expect("placement variant chosen from this accelerator");
                let service_ns =
                    (req.backlog_tiles as f64 * (v.compute_ns() + dma_est_ns)) as u64;
                if costs.reconfig_ns(v.regions) > service_ns {
                    return None;
                }
            }
        }
        Some(placement)
    }
}

/// Fixed-module baseline: each user keeps one 1-region module for the
/// whole run (Fig 15's comparison point).
#[derive(Debug, Default)]
pub struct Fixed {
    /// Per-user home region.
    home: Vec<Option<usize>>,
}

impl SchedPolicy for Fixed {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn retire(&mut self, user: usize) {
        // Release the departed tenant's home so it isn't phantom-owned
        // across slot recycling.
        if let Some(h) = self.home.get_mut(user) {
            *h = None;
        }
    }

    fn place(
        &mut self,
        regions: &RegionMap,
        _costs: &CostModel,
        req: &PlaceReq,
    ) -> Option<Placement> {
        if self.home.len() <= req.user {
            self.home.resize(req.user + 1, None);
        }
        // The smallest variant (variants are region-sorted, so index 0).
        let vsym = req.variant_syms[0];
        // A region we may (re)configure right now: neither running a
        // request itself nor the tail of a span whose anchor is — a
        // mixed-policy fabric (per-user policies) can have an elastic
        // tenant's multi-region module next to fixed homes, and only
        // the anchor carries the busy flag.
        let covering_busy = |r: usize| {
            let reg = regions.get(r);
            reg.busy || reg.tail_of.map(|t| regions.get(t).busy).unwrap_or(false)
        };
        let home = match self.home[req.user] {
            Some(r) => r,
            None => {
                // Claim the first region nobody owns; once every region
                // is owned, share one deterministically (pure
                // time-multiplexing) instead of starving the user.
                let owned: Vec<usize> = self.home.iter().flatten().copied().collect();
                match (0..regions.len())
                    .find(|&r| !owned.contains(&r) && !covering_busy(r))
                {
                    Some(r) => {
                        self.home[req.user] = Some(r);
                        r
                    }
                    None if (0..regions.len()).all(|r| owned.contains(&r)) => {
                        let n = regions.len();
                        let start = req.user % n;
                        let Some(r) =
                            (0..n).map(|k| (start + k) % n).find(|&r| !covering_busy(r))
                        else {
                            return None; // everything is running; wait
                        };
                        self.home[req.user] = Some(r);
                        r
                    }
                    None => return None, // an unowned region exists but is busy
                }
            }
        };
        if covering_busy(home) {
            return None; // our module (or the span over it) is busy; wait
        }
        let needs = regions
            .get(home)
            .loaded
            .map(|l| l.accel != req.accel_sym || l.variant != vsym)
            .unwrap_or(true);
        Some(Placement { anchor: home, variant: vsym, reconfigure: needs })
    }
}

/// Round-robin time-slicing (§4.4's time domain made preemptive):
/// requests run on the smallest variant; when a user is starved, the
/// longest-running request past the quantum is checkpointed and its
/// remainder requeued.  The paper's cooperative scheduler relinquishes
/// only *between* requests — this policy also relinquishes *within*
/// one, so a single streaming request can no longer monopolise a
/// module (the THEMIS-style fairness substrate).
#[derive(Debug)]
pub struct Quantum {
    /// Minimum virtual run time before a request may be preempted.
    pub quantum_ns: u64,
}

impl Default for Quantum {
    fn default() -> Quantum {
        // ~5 single-region reconfigurations on the Ultra96: long enough
        // that checkpoint/restore overhead stays marginal, short
        // against any streaming request worth preempting.
        Quantum { quantum_ns: 20_000_000 }
    }
}

impl SchedPolicy for Quantum {
    fn name(&self) -> &'static str {
        "quantum"
    }

    fn can_preempt(&self) -> bool {
        true
    }

    fn place(
        &mut self,
        regions: &RegionMap,
        _costs: &CostModel,
        req: &PlaceReq,
    ) -> Option<Placement> {
        let (v, vsym) = match req.pin {
            Some(p) => (req.variant_of(p)?, p),
            None => (req.accel.smallest_variant(), req.variant_syms[0]),
        };
        // Reuse an idle resident instance of exactly this variant.
        if let Some(anchor) = regions.idle_resident(req.accel_sym, vsym) {
            return Some(Placement { anchor, variant: vsym, reconfigure: false });
        }
        let anchor = regions.find_free_span(v.regions)?;
        Some(Placement { anchor, variant: vsym, reconfigure: true })
    }

    fn preempt(
        &mut self,
        _regions: &RegionMap,
        costs: &CostModel,
        running: &[RunningSnap],
        req: &PlaceReq,
        now: u64,
    ) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None; // (elapsed, anchor)
        for r in running {
            if r.user == req.user {
                continue; // preempting yourself buys no fairness
            }
            let elapsed = now.saturating_sub(r.start);
            if elapsed < self.quantum_ns {
                continue;
            }
            // Not worth splitting when the victim is nearly done.
            let remaining = r.end.saturating_sub(now);
            if remaining <= costs.checkpoint_ns(r.span) + costs.restore_ns(r.span) {
                continue;
            }
            if best.map(|(e, _)| elapsed > e).unwrap_or(true) {
                best = Some((elapsed, r.anchor));
            }
        }
        best.map(|(_, a)| a)
    }
}

/// Weighted tenant fair share (the THEMIS-style policy the tenant
/// plumbing exists for): while several tenants are active, a tenant's
/// concurrent spans are capped at `ceil(regions x weight /
/// active_weight)` (never below 1 — every tenant keeps a foothold),
/// and a tenant with *nothing* running that cannot place preempts the
/// dispatch of the tenant holding the most spans once it has run at
/// least [`FairShare::min_run_ns`].  Together with the admission
/// pipeline's weighted-DRR ingest this bounds any tenant's service
/// deficit: admission share tracks weights, fabric share is capped,
/// and starvation ends within one `min_run_ns` + preemption tick.
#[derive(Debug)]
pub struct FairShare {
    /// Minimum virtual run time before a dispatch may be preempted
    /// (keeps checkpoint/restore overhead amortised).
    pub min_run_ns: u64,
}

impl Default for FairShare {
    fn default() -> FairShare {
        // Half the Quantum policy's slice: fair share preempts only
        // for tenants with nothing running at all, so a shorter floor
        // bounds their wait without adding churn for balanced loads.
        FairShare { min_run_ns: 10_000_000 }
    }
}

impl SchedPolicy for FairShare {
    fn name(&self) -> &'static str {
        "fair"
    }

    fn can_preempt(&self) -> bool {
        true
    }

    fn place(
        &mut self,
        regions: &RegionMap,
        _costs: &CostModel,
        req: &PlaceReq,
    ) -> Option<Placement> {
        // Weighted share cap, enforced only under contention: a lone
        // tenant may use the whole fabric.
        if req.active_users > 1 {
            let aw = req.active_weight.max(1) as usize;
            let cap = (regions.len() * req.weight as usize).div_ceil(aw).max(1);
            if req.tenant_running >= cap {
                return None; // over fair share while others wait
            }
        }
        let (v, vsym) = match req.pin {
            Some(p) => (req.variant_of(p)?, p),
            None => (req.accel.smallest_variant(), req.variant_syms[0]),
        };
        // Reuse an idle resident instance of exactly this variant.
        if let Some(anchor) = regions.idle_resident(req.accel_sym, vsym) {
            return Some(Placement { anchor, variant: vsym, reconfigure: false });
        }
        let anchor = regions.find_free_span(v.regions)?;
        Some(Placement { anchor, variant: vsym, reconfigure: true })
    }

    fn preempt(
        &mut self,
        _regions: &RegionMap,
        costs: &CostModel,
        running: &[RunningSnap],
        req: &PlaceReq,
        now: u64,
    ) -> Option<usize> {
        // Only a tenant with nothing running anywhere may preempt —
        // the starvation-ending rule, not a general time-slicer.
        if running.iter().any(|r| r.tenant == req.tenant) {
            return None;
        }
        let mut best: Option<(usize, u64, usize)> = None; // (held regions, elapsed, anchor)
        for r in running {
            let elapsed = now.saturating_sub(r.start);
            if elapsed < self.min_run_ns {
                continue;
            }
            // Not worth splitting when the victim is nearly done.
            let remaining = r.end.saturating_sub(now);
            if remaining <= costs.checkpoint_ns(r.span) + costs.restore_ns(r.span) {
                continue;
            }
            // Biggest fabric holder first (regions, not dispatch count,
            // so a multi-region span weighs its full footprint).
            let share: usize = running
                .iter()
                .filter(|x| x.tenant == r.tenant)
                .map(|x| x.span)
                .sum();
            if best.map(|(s, e, _)| (share, elapsed) > (s, e)).unwrap_or(true) {
                best = Some((share, elapsed, r.anchor));
            }
        }
        best.map(|(_, _, a)| a)
    }
}

/// Per-tenant scheduling counters ([`SchedCore::tenant_counters`]) —
/// the scheduler half of the tenant observability surface (the
/// admission half lives in
/// [`super::admission::AdmissionPipeline::tenant_counters`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantSchedCounters {
    /// Requests accepted into this core's queues.
    pub admitted: u64,
    /// Dispatches whose registered running record completed.
    pub completed: u64,
    /// Running dispatches checkpointed ([`DecisionKind::Preempt`]).
    pub preempted: u64,
    /// Requests rejected by `next_decision` (unknown accelerator past
    /// admission, or a policy naming an unknown variant).
    pub rejected: u64,
}

/// Decision-log ring cap: plenty for tests/benches, bounded for a
/// long-lived daemon (overflow is counted, oldest entries dropped).
const LOG_CAP: usize = 65_536;

/// The shared scheduling state machine.  Pure: no I/O, no clocks — the
/// harness owns time (virtual or real) and hardware effects.
pub struct SchedCore {
    catalog: Catalog,
    /// Interned accelerator/variant names (hot path deals in [`Sym`]s).
    symbols: SymbolTable,
    /// Sym index → catalog accelerator index (`None` for variant syms).
    accel_of: Vec<Option<usize>>,
    /// Per catalog accelerator: its variants' syms, index-parallel.
    variant_syms: Vec<Vec<Sym>>,
    costs: CostModel,
    regions: RegionMap,
    queues: Vec<VecDeque<Request>>,
    /// Per-user queue statistics, maintained incrementally on every
    /// enqueue/dequeue so `pending`/`backlog_tiles`/`stealable_tiles`
    /// and the per-round `PlaceReq` inputs never rescan the queues.
    qstats: Vec<QueueStats>,
    /// Users with a non-empty queue, ascending — the round-robin scan
    /// set ([`SchedCore::next_user`] is `O(log users)` per pick).
    nonempty: BTreeSet<usize>,
    /// Mirrors of the queue totals (see `QueueStats`).
    pending_total: usize,
    backlog_total: usize,
    stealable_total: usize,
    rr: usize,
    /// Round stamp: `skip_round[u] == round_id` means `u` is deferred
    /// for the current round (an O(1) membership test that needs no
    /// per-round clearing, unlike the seed's `Vec<usize>` skip list).
    round_id: u64,
    skip_round: Vec<u64>,
    /// A deferred user of the current round is routed to a
    /// preemption-capable policy — the signal harnesses gate their
    /// [`PREEMPT_TICK_NS`] re-check rounds on.
    skip_preemptive: bool,
    counters: SchedCounters,
    log: VecDeque<Decision>,
    log_cap: usize,
    log_dropped: u64,
    policies: Vec<Box<dyn SchedPolicy>>,
    default_policy: usize,
    user_policy: Vec<usize>,
    /// Current virtual time (monotone; advanced by `begin_round_at`).
    now: u64,
    /// In-flight dispatches by anchor (ordered for deterministic
    /// victim iteration), registered via [`SchedCore::mark_running`].
    running: BTreeMap<usize, RunningSnap>,
    /// Progress records of preempted requests, by checkpoint id.
    checkpoints: BTreeMap<u64, Checkpoint>,
    /// Checkpoints consumed by a dispatched `Resume` but not yet
    /// completed — parked so a failed dispatch (reconfig fault) or a
    /// board-down drain can reconstruct the progress record instead of
    /// losing it.  Entries drop at the dispatch's completion, so the
    /// stash is bounded by the running set.
    consumed: BTreeMap<u64, Checkpoint>,
    next_ckpt: u64,
    /// Requests dropped by `next_decision` instead of panicking
    /// (unknown accelerator / policy chose an unknown variant); the
    /// harness drains these via [`SchedCore::take_rejected`] and fails
    /// the matching client replies.
    rejected: Vec<(Request, String)>,
    /// Per-tenant QoS weights ([`SchedCore::set_tenant_weight`]) —
    /// read by fair-share-aware policies through [`PlaceReq`].
    tenant_weights: BTreeMap<usize, u32>,
    /// Weighted memory-bandwidth partitioning
    /// ([`SchedCore::set_bw_partition`]): when on, [`SchedCore::
    /// service_ns`] charges DMA at the tenant's QoS share of the
    /// contended bandwidth instead of the per-master equal split.
    bw_partition: bool,
    /// Per-tenant scheduling counters (admitted / completed /
    /// preempted / rejected).
    per_tenant: BTreeMap<usize, TenantSchedCounters>,
    /// Round-scoped scratch buffers, reused across rounds so the
    /// dispatch loop allocates nothing in the steady state.
    scratch_snaps: Vec<RunningSnap>,
    scratch_tenants: Vec<usize>,
}

/// Incrementally maintained per-user queue statistics.
#[derive(Debug, Clone, Copy, Default)]
struct QueueStats {
    /// Queued tiles (the `PlaceReq::backlog_tiles` signal).
    tiles: usize,
    /// Queued tiles on non-resume requests (stealable backlog).
    steal_tiles: usize,
    /// Queued non-resume requests (donor eligibility).
    steal_reqs: usize,
}

impl SchedCore {
    /// Build a core for a shell with the built-in policies registered
    /// ([`Elastic`], [`Fixed`], [`Quantum`], [`Elastic::preemptive`])
    /// and `default` routing new users.
    pub fn new(shell: &Shell, catalog: Catalog, default: Policy) -> SchedCore {
        let symbols = SymbolTable::from_catalog(&catalog);
        let mut accel_of = vec![None; symbols.len()];
        let mut variant_syms = Vec::with_capacity(catalog.accelerators.len());
        for (ai, a) in catalog.accelerators.iter().enumerate() {
            let s = symbols.lookup(&a.name).expect("accelerator name interned");
            accel_of[s.index()] = Some(ai);
            variant_syms.push(
                a.variants
                    .iter()
                    .map(|v| symbols.lookup(&v.name).expect("variant name interned"))
                    .collect(),
            );
        }
        SchedCore {
            catalog,
            symbols,
            accel_of,
            variant_syms,
            costs: CostModel::new(shell),
            regions: RegionMap::new(shell),
            queues: Vec::new(),
            qstats: Vec::new(),
            nonempty: BTreeSet::new(),
            pending_total: 0,
            backlog_total: 0,
            stealable_total: 0,
            rr: 0,
            // Starts at 1 so fresh users' zeroed skip stamps are never
            // mistaken for "deferred this round".
            round_id: 1,
            skip_round: Vec::new(),
            skip_preemptive: false,
            counters: SchedCounters::default(),
            log: VecDeque::new(),
            log_cap: LOG_CAP,
            log_dropped: 0,
            policies: vec![
                Box::<Elastic>::default(),
                Box::<Fixed>::default(),
                Box::<Quantum>::default(),
                Box::new(Elastic::preemptive()),
                Box::<FairShare>::default(),
            ],
            default_policy: match default {
                Policy::Elastic => 0,
                Policy::Fixed => 1,
                Policy::Quantum => 2,
                Policy::ElasticPreempt => 3,
                Policy::FairShare => 4,
            },
            user_policy: Vec::new(),
            now: 0,
            running: BTreeMap::new(),
            checkpoints: BTreeMap::new(),
            consumed: BTreeMap::new(),
            next_ckpt: 0,
            rejected: Vec::new(),
            tenant_weights: BTreeMap::new(),
            bw_partition: false,
            per_tenant: BTreeMap::new(),
            scratch_snaps: Vec::new(),
            scratch_tenants: Vec::new(),
        }
    }

    /// The interned name table.  Deterministically derived from the
    /// catalog, so any holder of an equal catalog — every cluster
    /// shard, the daemon boundary, a test harness — can build an
    /// identical table with [`SymbolTable::from_catalog`] and exchange
    /// raw [`Sym`]s with this core.
    pub fn symbols(&self) -> &SymbolTable {
        &self.symbols
    }

    /// Resolve an interned accelerator/variant sym to its name — the
    /// RPC/trace-boundary escape hatch.
    pub fn resolve(&self, sym: Sym) -> &str {
        self.symbols.resolve(sym)
    }

    /// Account one enqueued request in the incremental queue stats.
    /// Call with the request being pushed, before or after the push.
    fn stats_add(&mut self, r: &Request) {
        let s = &mut self.qstats[r.user];
        s.tiles += r.tiles;
        if r.resume.is_none() {
            s.steal_tiles += r.tiles;
            s.steal_reqs += 1;
            self.stealable_total += r.tiles;
        }
        self.pending_total += 1;
        self.backlog_total += r.tiles;
        self.nonempty.insert(r.user);
    }

    /// Un-account one dequeued request.  Call AFTER removing it from
    /// its queue (the non-empty check reads the queue's new length).
    fn stats_remove(&mut self, r: &Request) {
        let s = &mut self.qstats[r.user];
        s.tiles -= r.tiles;
        if r.resume.is_none() {
            s.steal_tiles -= r.tiles;
            s.steal_reqs -= 1;
            self.stealable_total -= r.tiles;
        }
        self.pending_total -= 1;
        self.backlog_total -= r.tiles;
        if self.queues[r.user].is_empty() {
            self.nonempty.remove(&r.user);
        }
    }

    /// Set a tenant's QoS weight (default 1) — the fair-share signal
    /// [`PlaceReq::weight`] carries to policies.
    pub fn set_tenant_weight(&mut self, tenant: usize, weight: u32) {
        self.tenant_weights.insert(tenant, weight.max(1));
    }

    pub fn tenant_weight(&self, tenant: usize) -> u32 {
        self.tenant_weights.get(&tenant).copied().unwrap_or(1)
    }

    /// Enable/disable weighted memory-bandwidth partitioning (default
    /// off — service times then match the historical equal-split model
    /// exactly, which the golden decision fixture pins).
    pub fn set_bw_partition(&mut self, on: bool) {
        self.bw_partition = on;
    }

    pub fn bw_partition(&self) -> bool {
        self.bw_partition
    }

    /// Per-tenant scheduling counters, tenant id ascending.
    pub fn tenant_counters(&self) -> &BTreeMap<usize, TenantSchedCounters> {
        &self.per_tenant
    }

    /// Register an additional policy; returns its index. Tenants opt in
    /// via [`SchedCore::set_user_policy`] with the policy's name.
    pub fn register_policy(&mut self, policy: Box<dyn SchedPolicy>) -> usize {
        self.policies.push(policy);
        self.policies.len() - 1
    }

    /// Route `user` to the policy named `name`; `false` if unknown.
    pub fn set_user_policy(&mut self, user: usize, name: &str) -> bool {
        match self.policies.iter().position(|p| p.name() == name) {
            Some(idx) => {
                self.ensure_user(user);
                self.user_policy[user] = idx;
                true
            }
            None => false,
        }
    }

    pub fn policy_name_of(&self, user: usize) -> &'static str {
        let idx = self.user_policy.get(user).copied().unwrap_or(self.default_policy);
        self.policies[idx].name()
    }

    fn ensure_user(&mut self, user: usize) {
        if self.queues.len() <= user {
            self.queues.resize_with(user + 1, VecDeque::new);
            self.user_policy.resize(user + 1, self.default_policy);
            self.qstats.resize(user + 1, QueueStats::default());
            self.skip_round.resize(user + 1, 0);
        }
    }

    /// Admission check shared by [`SchedCore::submit`] and the cluster
    /// layer's routing (which must reject *before* consulting its
    /// placement policy): the accelerator, and the pinned variant if
    /// any, exist in the catalog.
    pub fn validate(&self, accel: &str, pin: Option<&str>) -> Result<(), String> {
        let known = match self.catalog.get(accel) {
            None => return Err(format!("no accelerator named {accel:?}")),
            Some(a) => a,
        };
        if let Some(p) = pin {
            if known.variant(p).is_none() {
                return Err(format!("no variant named {p:?} for accelerator {accel:?}"));
            }
        }
        Ok(())
    }

    /// Enqueue one acceleration request. Rejects unknown accelerators
    /// (and unknown pinned variants) so harnesses can fail fast.
    /// The request is accounted to tenant `user` — multi-connection
    /// tenants go through [`SchedCore::submit_for`].
    pub fn submit(
        &mut self,
        user: usize,
        job: u64,
        accel: &str,
        tiles: usize,
        pin: Option<&str>,
    ) -> Result<(), String> {
        self.submit_for(user, user, job, accel, tiles, pin)
    }

    /// [`SchedCore::submit`] with an explicit tenant tag (the daemon's
    /// admission pipeline maps several connections onto one tenant).
    pub fn submit_for(
        &mut self,
        user: usize,
        tenant: usize,
        job: u64,
        accel: &str,
        tiles: usize,
        pin: Option<&str>,
    ) -> Result<(), String> {
        self.validate(accel, pin)?;
        self.ensure_user(user);
        // Validation guarantees both names are catalog entries, and
        // every catalog name was interned at construction.
        let accel_sym = self.symbols.lookup(accel).expect("validated accelerator interned");
        let pin_sym = pin.map(|p| self.symbols.lookup(p).expect("validated variant interned"));
        let req = Request {
            user,
            tenant,
            job,
            accel: accel_sym,
            tiles: tiles.max(1),
            pin: pin_sym,
            resume: None,
        };
        self.stats_add(&req);
        self.queues[user].push_back(req);
        self.per_tenant.entry(tenant).or_default().admitted += 1;
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.pending_total
    }

    pub fn has_pending(&self) -> bool {
        self.pending_total > 0
    }

    /// Total queued tiles across every user — the backlog signal the
    /// cluster layer's placement policies and work-stealing rules read.
    /// O(1): maintained incrementally by the enqueue/dequeue paths.
    pub fn backlog_tiles(&self) -> usize {
        self.backlog_total
    }

    /// Queued tiles that work stealing may actually move — non-resume
    /// requests only (checkpointed remainders are pinned to this
    /// shard's hardware).  The cluster's donor selection reads this,
    /// not [`SchedCore::backlog_tiles`], so a queue full of pinned
    /// remainders is never mistaken for a stealable backlog.  O(1).
    pub fn stealable_tiles(&self) -> usize {
        self.stealable_total
    }

    /// Pop the most recently queued *non-resume* request from the user
    /// with the deepest stealable backlog — the donor half of
    /// cluster-level work stealing.  Requests pinned to a checkpoint
    /// are never stolen: their register-file snapshot lives on this
    /// shard's hardware and cannot be restored elsewhere.  `None` when
    /// nothing is stealable.
    pub fn steal_back(&mut self) -> Option<Request> {
        // Donor: deepest stealable backlog, lowest user on ties —
        // ascending scan with a strict `>` over the incremental stats
        // (identical pick to the seed's max_by_key over queue scans).
        let mut donor: Option<(usize, usize)> = None; // (steal_tiles, user)
        for (u, s) in self.qstats.iter().enumerate() {
            if s.steal_reqs == 0 {
                continue;
            }
            if donor.map(|(t, _)| s.steal_tiles > t).unwrap_or(true) {
                donor = Some((s.steal_tiles, u));
            }
        }
        let (_, user) = donor?;
        let q = &mut self.queues[user];
        let idx = q.iter().rposition(|r| r.resume.is_none())?;
        let r = q.remove(idx)?;
        self.stats_remove(&r);
        Some(r)
    }

    /// Enqueue a request stolen from another shard, fields preserved
    /// (the receiver half of work stealing).  Unlike [`SchedCore::submit`]
    /// this skips admission validation: the request was already admitted
    /// by the donor shard against the same catalog.
    pub fn inject(&mut self, req: Request) {
        self.ensure_user(req.user);
        self.stats_add(&req);
        self.queues[req.user].push_back(req);
    }

    /// Start a dispatch round: deferred users become eligible again.
    /// Call after every (virtual or real) time advance.
    pub fn begin_round(&mut self) {
        let now = self.now;
        self.begin_round_at(now);
    }

    /// [`SchedCore::begin_round`] with an explicit virtual timestamp —
    /// what both harnesses call.  The clock drives preemption progress
    /// accounting and is monotone (stale timestamps are ignored).
    pub fn begin_round_at(&mut self, now: u64) {
        self.now = self.now.max(now);
        // Advancing the round stamp invalidates every `skip_round`
        // mark at once — no O(users) clear.
        self.round_id += 1;
        self.skip_preemptive = false;
    }

    /// The shared preemption-tick cadence rule, called by a harness
    /// right after each scheduling round: when a preemption-capable
    /// policy deferred a user, work is running, and no tick is already
    /// pending past `now`, returns the virtual time at which the
    /// harness must schedule its next re-check round (and records it in
    /// the harness-owned `next_tick` slot).  Single-sourced here so the
    /// simulator and the daemon can never drift apart on it — that
    /// would silently break decision parity.
    pub fn preempt_tick_due(&self, next_tick: &mut Option<u64>, now: u64) -> Option<u64> {
        if self.skip_preemptive
            && !self.running.is_empty()
            && next_tick.map_or(true, |t| t <= now)
        {
            let t = now + PREEMPT_TICK_NS;
            *next_tick = Some(t);
            Some(t)
        } else {
            None
        }
    }

    /// In-flight dispatches currently registered.
    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// Register a dispatched decision's virtual execution window so the
    /// core can account preemption progress.  Call right after
    /// computing the decision's service time; `Preempt` decisions are
    /// ignored.  The record is dropped by [`SchedCore::complete`] or by
    /// a later preemption of the anchor.
    pub fn mark_running(&mut self, d: &Decision, start: u64, end: u64) {
        if d.kind == DecisionKind::Preempt {
            return;
        }
        let mut setup = if d.reconfigure { self.costs.reconfig_ns(d.span) } else { 0 };
        if d.kind == DecisionKind::Resume {
            setup += self.costs.checkpoint_ns(d.span) + self.costs.restore_ns(d.span);
        }
        let setup = setup.min(end.saturating_sub(start));
        self.running.insert(
            d.anchor,
            RunningSnap {
                user: d.user,
                tenant: d.tenant,
                job: d.job,
                accel: d.accel,
                variant: d.variant,
                anchor: d.anchor,
                span: d.span,
                tiles: d.tiles,
                start,
                end,
                setup,
                resumed: d.kind == DecisionKind::Resume,
                ckpt: if d.kind == DecisionKind::Resume { d.ckpt } else { None },
            },
        );
    }

    /// Requests `next_decision` rejected (with the reason) instead of
    /// panicking — unknown accelerator past admission or a policy
    /// naming an unknown variant.  The harness fails the matching
    /// replies; the dispatcher stays alive.
    pub fn take_rejected(&mut self) -> Vec<(Request, String)> {
        std::mem::take(&mut self.rejected)
    }

    /// Progress record of a live checkpoint (created by a `Preempt`
    /// decision, consumed by its `Resume`).
    pub fn checkpoint(&self, id: u64) -> Option<&Checkpoint> {
        self.checkpoints.get(&id)
    }

    /// Live (unconsumed) checkpoints, oldest id first.
    pub fn checkpoints(&self) -> impl Iterator<Item = (u64, &Checkpoint)> {
        self.checkpoints.iter().map(|(&id, c)| (id, c))
    }

    /// Round-robin pick of the next user with pending, non-deferred
    /// work.  Walks the non-empty-user index from the RR cursor (with
    /// wrap-around) instead of scanning every queue, so a pick costs
    /// `O(log users + deferred)` rather than `O(users)`.
    fn next_user(&mut self) -> Option<usize> {
        let n = self.queues.len();
        let u = self
            .nonempty
            .range(self.rr..)
            .chain(self.nonempty.range(..self.rr))
            .copied()
            .find(|&u| self.skip_round[u] != self.round_id)?;
        self.rr = (u + 1) % n;
        Some(u)
    }

    /// Produce the next placement of the current round, applying it to
    /// the region map (module loaded/replaced, anchor marked busy) and
    /// the counters.  `None` ends the round: every user is drained or
    /// deferred.  The harness must later call
    /// [`SchedCore::complete`] for the decision's anchor.
    pub fn next_decision(&mut self) -> Option<Decision> {
        loop {
            let user = self.next_user()?;
            let head = *self.queues[user].front().unwrap();
            let backlog_tiles = self.qstats[user].tiles;
            let active_users = self.nonempty.len();
            let now = self.now;
            // Fair-share inputs: the tenant's in-flight span count and
            // the total weight of every active tenant (pending work or
            // a running dispatch), computed before the split borrow.
            // The tenant set is collected into a reused scratch buffer
            // (sort + dedup) instead of a fresh BTreeSet per round.
            let tenant = head.tenant;
            let tenant_running: usize = self
                .running
                .values()
                .filter(|r| r.tenant == tenant)
                .map(|r| r.span)
                .sum();
            let weight = self.tenant_weight(tenant);
            let active_weight: u32 = {
                let mut active = std::mem::take(&mut self.scratch_tenants);
                active.clear();
                active.extend(
                    self.nonempty
                        .iter()
                        .filter_map(|&u| self.queues[u].front().map(|r| r.tenant)),
                );
                active.extend(self.running.values().map(|r| r.tenant));
                active.sort_unstable();
                active.dedup();
                let w = active.iter().map(|&t| self.tenant_weight(t)).sum();
                self.scratch_tenants = active;
                w
            };

            // Split-borrow the fields so a stateful policy can mutate
            // itself while reading regions/costs.
            let SchedCore {
                catalog,
                costs,
                regions,
                policies,
                user_policy,
                default_policy,
                running,
                accel_of,
                variant_syms,
                scratch_snaps,
                ..
            } = self;
            let Some(ai) = accel_of.get(head.accel.index()).copied().flatten() else {
                // Unknown accelerator past admission (`submit` validates,
                // so only a harness bug or catalog swap gets here):
                // reject the request back to the harness instead of
                // killing the dispatcher.
                let request = self.queues[user].pop_front().unwrap();
                self.stats_remove(&request);
                let reason =
                    format!("no accelerator named {:?}", self.symbols.resolve(request.accel));
                self.drop_checkpoint_of(&request);
                self.per_tenant.entry(request.tenant).or_default().rejected += 1;
                self.rejected.push((request, reason));
                continue;
            };
            let accel = &catalog.accelerators[ai];
            let req = PlaceReq {
                user,
                tenant,
                accel,
                accel_sym: head.accel,
                variant_syms: &variant_syms[ai][..],
                pin: head.pin,
                backlog_tiles,
                active_users,
                tenant_running,
                weight,
                active_weight,
            };
            let idx = user_policy.get(user).copied().unwrap_or(*default_policy);
            let Some(p) = policies[idx].place(regions, costs, &req) else {
                // No placement: the policy may checkpoint a running
                // span instead of deferring (time-domain elasticity).
                // The running-set snapshot is only built for policies
                // that can actually use it — into a reused scratch
                // buffer (records are `Copy`), not a fresh Vec.
                let preemptive = policies[idx].can_preempt();
                let victim = if preemptive {
                    scratch_snaps.clear();
                    scratch_snaps.extend(running.values().copied());
                    policies[idx].preempt(regions, costs, &scratch_snaps[..], &req, now)
                } else {
                    None
                };
                if let Some(anchor) = victim {
                    if let Some(d) = self.preempt_anchor(anchor) {
                        // Hand the freed span to the starved requester
                        // first: plain round-robin could give it right
                        // back to the victim's requeued remainder
                        // (preemption thrash, no progress for anyone).
                        self.rr = user;
                        return Some(d);
                    }
                }
                self.counters.skips += 1;
                self.skip_round[user] = self.round_id;
                self.skip_preemptive |= preemptive;
                continue;
            };

            let Some(span) = variant_syms[ai]
                .iter()
                .position(|&s| s == p.variant)
                .map(|vi| accel.variants[vi].regions)
            else {
                // A buggy policy chose a variant the catalog does not
                // know: reject the request (the client learns why)
                // rather than panicking the dispatcher.
                let pname = policies[idx].name();
                let request = self.queues[user].pop_front().unwrap();
                self.stats_remove(&request);
                let reason = format!(
                    "policy {pname:?} chose unknown variant {:?} for {:?}",
                    self.symbols.resolve(p.variant),
                    self.symbols.resolve(request.accel)
                );
                self.drop_checkpoint_of(&request);
                self.per_tenant.entry(request.tenant).or_default().rejected += 1;
                self.rejected.push((request, reason));
                continue;
            };
            let request = self.queues[user].pop_front().unwrap();
            self.stats_remove(&request);
            if p.reconfigure {
                self.regions.install(
                    p.anchor,
                    span,
                    LoadedModule { accel: request.accel, variant: p.variant, span },
                );
                self.counters.reconfigs += 1;
            } else {
                self.counters.reuses += 1;
            }
            self.regions.regions[p.anchor].busy = true;
            for r in p.anchor..p.anchor + span {
                self.regions.touch(r);
            }
            // Replication: after this placement, is the same
            // accelerator resident at any other anchor?  O(log) via
            // the residency index.
            let replicated = self.regions.replicated_elsewhere(request.accel, p.anchor);
            if replicated && p.reconfigure {
                self.counters.replications += 1;
            }
            let (kind, ckpt) = match request.resume {
                Some(id) => {
                    self.counters.resumes += 1;
                    // Park the progress record in the consumed stash
                    // (dropped at completion): a failed dispatch or a
                    // board-down drain can then reconstruct it instead
                    // of losing the checkpointed progress.
                    if let Some(c) = self.checkpoints.remove(&id) {
                        self.consumed.insert(id, c);
                    }
                    (DecisionKind::Resume, Some(id))
                }
                None => (DecisionKind::Run, None),
            };

            let d = Decision {
                user,
                tenant: request.tenant,
                job: request.job,
                accel: request.accel,
                variant: p.variant,
                anchor: p.anchor,
                span,
                tiles: request.tiles,
                reconfigure: p.reconfigure,
                replicated,
                kind,
                ckpt,
                pin: request.pin,
            };
            self.log_decision(&d);
            return Some(d);
        }
    }

    /// Append a decision to the ring-capped log (oldest dropped and
    /// counted past the cap).
    fn log_decision(&mut self, d: &Decision) {
        if self.log.len() >= self.log_cap {
            self.log.pop_front();
            self.log_dropped += 1;
        }
        self.log.push_back(*d);
    }

    /// Override the decision-log ring cap (default 65 536) — for ops
    /// tuning a long-lived daemon's memory, and for tests exercising
    /// the wrap boundary without pushing 65k decisions.
    pub fn set_log_cap(&mut self, cap: usize) {
        self.log_cap = cap.max(1);
        while self.log.len() > self.log_cap {
            self.log.pop_front();
            self.log_dropped += 1;
        }
    }

    /// Checkpoint the request running at `anchor` *now*: record its
    /// progress, free the span, requeue the remainder at the front of
    /// the victim's queue (pinned to the checkpointed variant), and
    /// emit the `Preempt` decision.  `None` when there is no running
    /// record, the dispatch only just started, or it is about to finish
    /// anyway — the caller then falls back to deferring.
    fn preempt_anchor(&mut self, anchor: usize) -> Option<Decision> {
        let rec = self.running.get(&anchor)?;
        if self.now <= rec.start {
            return None; // same-instant preemption would waste the dispatch
        }
        let run_ns = self.now - rec.start;
        let done = if run_ns <= rec.setup {
            0
        } else {
            // Linear progress over the compute window (u128: the
            // product can exceed u64 for long virtual runs).
            let window = rec.end.saturating_sub(rec.start + rec.setup).max(1);
            (((run_ns - rec.setup) as u128 * rec.tiles as u128) / window as u128) as usize
        };
        let done = done.min(rec.tiles);
        let remaining = rec.tiles - done;
        if remaining == 0 {
            return None; // completing this instant: let it finish
        }
        let rec = self.running.remove(&anchor).unwrap();
        self.regions.regions[anchor].busy = false;
        // A preempted Resume supersedes the checkpoint it had consumed:
        // its progress is folded into the new record's tile counts.
        if let Some(old) = rec.ckpt {
            self.consumed.remove(&old);
        }
        let id = self.next_ckpt;
        self.next_ckpt += 1;
        self.checkpoints.insert(
            id,
            Checkpoint {
                accel: rec.accel,
                variant: rec.variant,
                anchor,
                span: rec.span,
                tiles_done: done,
                tiles_total: rec.tiles,
            },
        );
        self.ensure_user(rec.user);
        let req = Request {
            user: rec.user,
            tenant: rec.tenant,
            job: rec.job,
            accel: rec.accel,
            tiles: remaining,
            pin: Some(rec.variant),
            resume: Some(id),
        };
        self.stats_add(&req);
        self.queues[rec.user].push_front(req);
        self.counters.preemptions += 1;
        self.per_tenant.entry(rec.tenant).or_default().preempted += 1;
        let d = Decision {
            user: rec.user,
            tenant: rec.tenant,
            job: rec.job,
            accel: rec.accel,
            variant: rec.variant,
            anchor,
            span: rec.span,
            tiles: remaining,
            reconfigure: false,
            replicated: false,
            kind: DecisionKind::Preempt,
            ckpt: Some(id),
            pin: Some(rec.variant),
        };
        self.log_decision(&d);
        Some(d)
    }

    /// The request running at `anchor` finished; its module stays
    /// resident (reuse fodder) but the span is idle again.  When a
    /// running record was registered ([`SchedCore::mark_running`]) the
    /// tenant's `completed` counter is credited.
    pub fn complete(&mut self, anchor: usize) {
        self.regions.regions[anchor].busy = false;
        if let Some(rec) = self.running.remove(&anchor) {
            self.per_tenant.entry(rec.tenant).or_default().completed += 1;
            // A completed Resume's parked progress record is obsolete.
            if let Some(id) = rec.ckpt {
                self.consumed.remove(&id);
            }
        }
    }

    /// Roll back a placement whose hardware effect failed: the module
    /// the last decision recorded at `anchor` is NOT actually resident,
    /// so forget it (and its tails) — otherwise the reuse path would
    /// keep preferring a phantom instance forever. The anchor's `busy`
    /// flag is untouched; the harness still owns the completion.
    pub fn evict(&mut self, anchor: usize) {
        self.regions.evict_anchor(anchor);
    }

    // ---- failure domain (see cluster.rs for the recovery policy) ----

    /// Roll back a dispatched decision whose hardware effect failed
    /// (injected or real reconfiguration fault): the span is freed, the
    /// phantom module forgotten, the running record (when already
    /// registered) dropped, and the original [`Request`] reconstructed
    /// — a consumed checkpoint goes back to the live store so the
    /// retried `Resume` still restores its progress.  The caller (the
    /// cluster layer's [`reconfig_outcome`]) decides between a backoff
    /// retry and a structured rejection.
    ///
    /// [`reconfig_outcome`]: super::ClusterCore::reconfig_outcome
    pub fn rollback_failed_dispatch(&mut self, d: &Decision) -> Request {
        self.regions.regions[d.anchor].busy = false;
        self.evict(d.anchor);
        self.running.remove(&d.anchor);
        let resume = match (d.kind, d.ckpt) {
            (DecisionKind::Resume, Some(id)) => {
                if let Some(c) = self.consumed.remove(&id) {
                    self.checkpoints.insert(id, c);
                }
                Some(id)
            }
            _ => None,
        };
        Request {
            user: d.user,
            tenant: d.tenant,
            job: d.job,
            accel: d.accel,
            tiles: d.tiles,
            pin: d.pin,
            resume,
        }
    }

    /// Push a request into the rejected buffer with a structured
    /// reason — the fault layer's terminal path once the retry cap is
    /// spent — dropping any checkpoint it was due to consume.
    pub fn push_rejected(&mut self, req: Request, reason: String) {
        self.drop_checkpoint_of(&req);
        self.per_tenant.entry(req.tenant).or_default().rejected += 1;
        self.rejected.push((req, reason));
    }

    /// A running dispatch's execution failed transiently (injected
    /// `TransientRunError`): free the span — the module itself stays
    /// resident, the load was fine — and requeue the whole dispatch at
    /// the front of its owner's queue for a clean re-run.  Returns the
    /// virtual time the failed dispatch burned; `None` when nothing
    /// runs at `anchor`.
    pub fn fail_running(&mut self, anchor: usize, now: u64) -> Option<u64> {
        let rec = self.running.remove(&anchor)?;
        self.regions.regions[anchor].busy = false;
        // A failed Resume already consumed its checkpoint; the progress
        // survives in the record's (remainder) tile count, so the
        // parked progress record is obsolete.
        if let Some(id) = rec.ckpt {
            self.consumed.remove(&id);
        }
        self.ensure_user(rec.user);
        let req = Request {
            user: rec.user,
            tenant: rec.tenant,
            job: rec.job,
            accel: rec.accel,
            tiles: rec.tiles,
            pin: Some(rec.variant),
            resume: None,
        };
        self.stats_add(&req);
        self.queues[rec.user].push_front(req);
        Some(now.saturating_sub(rec.start))
    }

    /// Drain every running dispatch for board failover: each record is
    /// checkpointed at `now` (progress computed exactly like a
    /// preemption, clamped so at least one tile remains), its span
    /// freed, a `Preempt` decision logged — the migration shows up in
    /// the decision sequence — and the remainder returned for the
    /// cluster layer to re-inject into a healthy shard.  The progress
    /// record travels WITH the remainder (the target shard adopts it
    /// under a fresh id) instead of entering this shard's store — this
    /// board's hardware is gone.  `keep_progress: false` is the
    /// drop-and-resubmit baseline: remainders restart from zero tiles.
    pub fn drain_running_for_failover(
        &mut self,
        now: u64,
        keep_progress: bool,
    ) -> Vec<FailoverDrain> {
        let anchors: Vec<usize> = self.running.keys().copied().collect();
        let mut out = Vec::new();
        for anchor in anchors {
            let rec = self.running.remove(&anchor).unwrap();
            self.regions.regions[anchor].busy = false;
            if let Some(id) = rec.ckpt {
                self.consumed.remove(&id);
            }
            let run_ns = now.saturating_sub(rec.start);
            let window = rec.end.saturating_sub(rec.start + rec.setup).max(1);
            let done = if !keep_progress || run_ns <= rec.setup {
                0
            } else {
                ((((run_ns - rec.setup) as u128 * rec.tiles as u128) / window as u128)
                    as usize)
                    .min(rec.tiles.saturating_sub(1))
            };
            let remaining = rec.tiles - done;
            // Work the failure destroyed: everything this dispatch
            // spent minus the compute window of the tiles whose
            // progress the checkpoint preserves.
            let saved = (done as u128 * window as u128 / rec.tiles as u128) as u64;
            let lost_ns = run_ns.saturating_sub(saved);
            let checkpoint = (done > 0).then(|| Checkpoint {
                accel: rec.accel,
                variant: rec.variant,
                anchor,
                span: rec.span,
                tiles_done: done,
                tiles_total: rec.tiles,
            });
            if checkpoint.is_some() {
                self.counters.preemptions += 1;
                self.per_tenant.entry(rec.tenant).or_default().preempted += 1;
            }
            let d = Decision {
                user: rec.user,
                tenant: rec.tenant,
                job: rec.job,
                accel: rec.accel,
                variant: rec.variant,
                anchor,
                span: rec.span,
                tiles: remaining,
                reconfigure: false,
                replicated: false,
                kind: DecisionKind::Preempt,
                ckpt: None,
                pin: Some(rec.variant),
            };
            self.log_decision(&d);
            let request = Request {
                user: rec.user,
                tenant: rec.tenant,
                job: rec.job,
                accel: rec.accel,
                tiles: remaining,
                pin: Some(rec.variant),
                // The target shard sets this when adopting `checkpoint`.
                resume: None,
            };
            out.push(FailoverDrain { decision: d, request, checkpoint, lost_ns, done, anchor });
        }
        out
    }

    /// [`SchedCore::drain_pending`] for board failover: unlike the
    /// normal drain — which drops the checkpoint a departing
    /// resume-request was due to consume — each request leaves
    /// TOGETHER with its progress record, so the cluster layer can
    /// re-home both on the adopting shard.
    pub fn drain_pending_with_checkpoints(&mut self) -> Vec<(Request, Option<Checkpoint>)> {
        let mut out = Vec::new();
        for u in 0..self.queues.len() {
            while let Some(r) = self.queues[u].pop_front() {
                self.stats_remove(&r);
                let ck = r.resume.and_then(|id| self.checkpoints.remove(&id));
                out.push((r, ck));
            }
        }
        out
    }

    /// Adopt a migrated progress record under a fresh checkpoint id —
    /// the receiving half of checkpoint-based migration.
    pub fn adopt_checkpoint(&mut self, c: Checkpoint) -> u64 {
        let id = self.next_ckpt;
        self.next_ckpt += 1;
        self.checkpoints.insert(id, c);
        id
    }

    /// Remove and return a live checkpoint — a queued remainder leaving
    /// this shard (board failover) takes its progress record along.
    pub fn take_checkpoint(&mut self, id: u64) -> Option<Checkpoint> {
        self.checkpoints.remove(&id)
    }

    /// Forget every resident module (a failed board comes back blank):
    /// after a revival the reuse path must reconfigure from scratch
    /// instead of trusting pre-failure residency.
    pub fn clear_residency(&mut self) {
        self.regions.clear_all();
    }

    /// Drop the checkpoint a resume-request was due to consume — called
    /// whenever such a request leaves the system by any path other than
    /// a `Resume` dispatch (retire, drain, reject), so the store never
    /// accumulates orphaned progress records in a long-lived daemon.
    fn drop_checkpoint_of(&mut self, req: &Request) {
        if let Some(id) = req.resume {
            self.checkpoints.remove(&id);
        }
    }

    /// A user departed: drop their queued requests (returned so the
    /// harness can fail the matching replies) and any checkpoints those
    /// requests were due to consume, reset their policy routing, and
    /// let every policy drop its per-user state so the slot can be
    /// recycled cleanly for a future tenant.
    pub fn retire_user(&mut self, user: usize) -> Vec<Request> {
        if user >= self.queues.len() {
            return Vec::new();
        }
        self.user_policy[user] = self.default_policy;
        for p in &mut self.policies {
            p.retire(user);
        }
        // Forget the departed user's running records too: the slot may
        // be recycled to a new tenant before those dispatches complete,
        // and a later preemption of one would otherwise requeue the
        // ghost remainder into the new tenant's queue (and make the
        // starvation checks see the ghost as the new tenant's work).
        // The spans stay busy until the harness replays their
        // completions; they just can no longer be preempted.
        let stale: Vec<u64> = self
            .running
            .values()
            .filter(|r| r.user == user)
            .filter_map(|r| r.ckpt)
            .collect();
        for id in stale {
            self.consumed.remove(&id);
        }
        self.running.retain(|_, r| r.user != user);
        let mut out = Vec::new();
        while let Some(r) = self.queues[user].pop_front() {
            self.stats_remove(&r);
            self.drop_checkpoint_of(&r);
            out.push(r);
        }
        out
    }

    /// Drain every queued request (dispatcher stall-guard: lets a
    /// harness fail requests no policy will ever place), dropping the
    /// checkpoints the drained resume-requests were due to consume.
    pub fn drain_pending(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        for u in 0..self.queues.len() {
            while let Some(r) = self.queues[u].pop_front() {
                self.stats_remove(&r);
                self.drop_checkpoint_of(&r);
                out.push(r);
            }
        }
        out
    }

    /// Virtual service latency of a decision under `concurrent` other
    /// busy modules: per-tile (DMA + compute) x tiles, plus the partial
    /// reconfiguration when one was paid.  A `Resume` additionally
    /// carries the preemption overhead — the checkpoint of the slice it
    /// continues plus its own context restore (both charged to the
    /// preempted request, never to the tenant that displaced it).
    pub fn service_ns(&self, d: &Decision, concurrent: usize) -> u64 {
        let ai = self
            .accel_of
            .get(d.accel.index())
            .copied()
            .flatten()
            .expect("decision for unknown accel");
        let accel = &self.catalog.accelerators[ai];
        let vi = self.variant_syms[ai]
            .iter()
            .position(|&s| s == d.variant)
            .expect("decision for unknown variant");
        let variant = &accel.variants[vi];
        let per_tile = if self.bw_partition {
            // Partition the DMA legs by QoS weight over the tenants
            // with running dispatches (this one counts as active).
            // Deterministic: the running set is anchor-ordered and
            // both harnesses call at identical points, so parity holds
            // with the knob on or off.
            let weight = self.tenant_weight(d.tenant);
            let mut active_weight = weight;
            let mut tenant_masters = 1usize;
            for (i, s) in self.running.values().enumerate() {
                if s.tenant == d.tenant {
                    tenant_masters += 1;
                } else if !self.running.values().take(i).any(|p| p.tenant == s.tenant) {
                    // First running dispatch of this foreign tenant
                    // (the running set is small — bounded by regions —
                    // so the quadratic scan is cheaper than a set).
                    active_weight += self.tenant_weight(s.tenant);
                }
            }
            self.costs.per_tile_ns_partitioned(
                accel,
                variant,
                weight,
                active_weight,
                tenant_masters,
                concurrent,
            )
        } else {
            self.costs.per_tile_ns(accel, variant, concurrent)
        };
        let mut ns = (per_tile * d.tiles as f64) as u64;
        if d.reconfigure {
            ns += self.costs.reconfig_ns(d.span);
        }
        if d.kind == DecisionKind::Resume {
            ns += self.costs.checkpoint_ns(d.span) + self.costs.restore_ns(d.span);
        }
        ns
    }

    pub fn counters(&self) -> &SchedCounters {
        &self.counters
    }

    /// Ordered decision history (oldest dropped past the ring cap).
    pub fn decision_log(&self) -> impl Iterator<Item = &Decision> {
        self.log.iter()
    }

    /// The last `n` decisions in order — O(1) positioning (no full-ring
    /// scan), so monitoring queries never walk the whole log.
    pub fn decision_log_tail(&self, n: usize) -> impl Iterator<Item = &Decision> {
        self.log.iter().skip(self.log.len().saturating_sub(n))
    }

    pub fn decisions_dropped(&self) -> u64 {
        self.log_dropped
    }

    pub fn costs(&self) -> &CostModel {
        &self.costs
    }

    pub fn regions(&self) -> &RegionMap {
        &self.regions
    }

    pub fn busy_anchors(&self) -> usize {
        self.regions.busy_anchors()
    }

    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shell::{Shell, ShellBoard};

    fn catalog() -> Catalog {
        Catalog::load_default().unwrap()
    }

    fn core(policy: Policy) -> SchedCore {
        SchedCore::new(&Shell::build(ShellBoard::Ultra96), catalog(), policy)
    }

    #[test]
    fn elastic_reuses_resident_idle_instance() {
        let mut c = core(Policy::Elastic);
        c.submit(0, 0, "sobel", 1, None).unwrap();
        c.begin_round();
        let d1 = c.next_decision().unwrap();
        assert!(d1.reconfigure);
        c.complete(d1.anchor);
        c.submit(0, 1, "sobel", 1, None).unwrap();
        c.begin_round();
        let d2 = c.next_decision().unwrap();
        assert!(!d2.reconfigure, "idle instance must be reused: {d2:?}");
        assert_eq!(d2.anchor, d1.anchor);
        assert_eq!(c.counters().reuses, 1);
        assert_eq!(c.counters().reconfigs, 1);
    }

    #[test]
    fn single_tenant_backlog_replicates() {
        let mut c = core(Policy::Elastic);
        for j in 0..3 {
            // Long-running tiles so replication amortises reconfigs.
            c.submit(0, j, "mandelbrot", 8, Some("mandelbrot_v1")).unwrap();
        }
        c.begin_round();
        let mut anchors = Vec::new();
        while let Some(d) = c.next_decision() {
            anchors.push(d.anchor);
        }
        anchors.sort_unstable();
        anchors.dedup();
        assert!(anchors.len() >= 2, "expected replication, got {anchors:?}");
        assert!(c.counters().replications >= 1);
    }

    #[test]
    fn round_robin_alternates_users() {
        let mut c = core(Policy::Elastic);
        for j in 0..2 {
            c.submit(0, j, "mandelbrot", 8, Some("mandelbrot_v1")).unwrap();
            c.submit(1, 10 + j, "sobel", 8, Some("sobel_v1")).unwrap();
        }
        c.begin_round();
        let mut users = Vec::new();
        while let Some(d) = c.next_decision() {
            users.push(d.user);
        }
        assert!(users.starts_with(&[0, 1]), "RR order violated: {users:?}");
    }

    #[test]
    fn fixed_users_keep_one_region() {
        let mut c = core(Policy::Fixed);
        for j in 0..4 {
            c.submit(0, j, "sobel", 1, None).unwrap();
            c.submit(1, 10 + j, "dct", 1, None).unwrap();
        }
        let mut homes: std::collections::HashMap<usize, std::collections::HashSet<usize>> =
            Default::default();
        loop {
            c.begin_round();
            let mut any = false;
            let mut done = Vec::new();
            while let Some(d) = c.next_decision() {
                any = true;
                assert_eq!(d.span, 1);
                homes.entry(d.user).or_default().insert(d.anchor);
                done.push(d.anchor);
            }
            for a in done {
                c.complete(a);
            }
            if !any && !c.has_pending() {
                break;
            }
        }
        for (u, regions) in homes {
            assert_eq!(regions.len(), 1, "user {u} moved between {regions:?}");
        }
    }

    #[test]
    fn fixed_oversubscription_shares_instead_of_starving() {
        let mut c = core(Policy::Fixed); // Ultra96: 3 regions, 4 users
        for u in 0..4 {
            c.submit(u, u as u64, "vadd", 1, None).unwrap();
        }
        let mut served = std::collections::HashSet::new();
        for _ in 0..16 {
            c.begin_round();
            let mut done = Vec::new();
            while let Some(d) = c.next_decision() {
                served.insert(d.user);
                done.push(d.anchor);
            }
            for a in done {
                c.complete(a);
            }
            if !c.has_pending() {
                break;
            }
        }
        assert_eq!(served.len(), 4, "all users must eventually be served");
        assert!(!c.has_pending());
    }

    #[test]
    fn per_user_policy_routing() {
        let mut c = core(Policy::Elastic);
        assert!(c.set_user_policy(1, "fixed"));
        assert!(!c.set_user_policy(1, "themis"));
        assert_eq!(c.policy_name_of(0), "elastic");
        assert_eq!(c.policy_name_of(1), "fixed");
        // Elastic user with a single-tenant backlog may span regions;
        // the fixed user stays on 1-region modules.
        for j in 0..2 {
            c.submit(1, j, "dct", 50, None).unwrap();
        }
        c.begin_round();
        let d = c.next_decision().unwrap();
        assert_eq!(d.span, 1, "fixed tenant must get the smallest variant");
    }

    #[test]
    fn unknown_names_rejected_at_submit() {
        let mut c = core(Policy::Elastic);
        assert!(c.submit(0, 0, "flux_capacitor", 1, None).is_err());
        assert!(c.submit(0, 0, "vadd", 1, Some("vadd_v9")).is_err());
        assert!(!c.has_pending());
    }

    #[test]
    fn lru_replacement_prefers_blank_then_oldest() {
        let mut c = core(Policy::Elastic);
        // Load sobel, complete; then mandelbrot must take a blank
        // region, not destroy the reusable sobel instance.
        c.submit(0, 0, "sobel", 1, Some("sobel_v1")).unwrap();
        c.begin_round();
        let d = c.next_decision().unwrap();
        c.complete(d.anchor);
        c.submit(0, 1, "mandelbrot", 1, Some("mandelbrot_v1")).unwrap();
        c.begin_round();
        let d2 = c.next_decision().unwrap();
        assert_ne!(d2.anchor, d.anchor, "blank region must be preferred over eviction");
        c.complete(d2.anchor);
        // Sobel is still resident: a reuse, not a reconfig.
        c.submit(0, 2, "sobel", 1, Some("sobel_v1")).unwrap();
        c.begin_round();
        let d3 = c.next_decision().unwrap();
        assert!(!d3.reconfigure);
        assert_eq!(d3.anchor, d.anchor);
    }

    #[test]
    fn retire_clears_policy_state() {
        let mut c = core(Policy::Fixed);
        c.submit(0, 0, "vadd", 1, None).unwrap();
        c.begin_round();
        let d = c.next_decision().unwrap();
        c.complete(d.anchor);
        assert!(c.retire_user(0).is_empty());
        // The recycled slot plus two new tenants must claim all three
        // regions — no phantom ownership of the departed user's home.
        for u in 0..3 {
            c.submit(u, 10 + u as u64, "vadd", 1, None).unwrap();
        }
        c.begin_round();
        let mut anchors: Vec<usize> = Vec::new();
        while let Some(d) = c.next_decision() {
            anchors.push(d.anchor);
        }
        anchors.sort_unstable();
        assert_eq!(anchors, vec![0, 1, 2]);
    }

    #[test]
    fn evict_forgets_phantom_residency() {
        let mut c = core(Policy::Elastic);
        c.submit(0, 0, "sobel", 1, Some("sobel_v1")).unwrap();
        c.begin_round();
        let d = c.next_decision().unwrap();
        assert!(d.reconfigure);
        // Harness reports the load failed: roll back, then complete.
        c.evict(d.anchor);
        c.complete(d.anchor);
        // The next identical request must reconfigure again, not reuse.
        c.submit(0, 1, "sobel", 1, Some("sobel_v1")).unwrap();
        c.begin_round();
        let d2 = c.next_decision().unwrap();
        assert!(d2.reconfigure, "phantom module must not be reused: {d2:?}");
    }

    #[test]
    fn counters_sum_to_placements() {
        let mut c = core(Policy::Elastic);
        let mut placements = 0u64;
        for j in 0..6 {
            c.submit(j % 2, j, "fir", 2, None).unwrap();
        }
        loop {
            c.begin_round();
            let mut done = Vec::new();
            while let Some(d) = c.next_decision() {
                placements += 1;
                done.push(d.anchor);
            }
            for a in done {
                c.complete(a);
            }
            if !c.has_pending() {
                break;
            }
        }
        let cts = c.counters();
        assert_eq!(cts.reconfigs + cts.reuses, placements);
        assert_eq!(placements, 6);
        assert_eq!(c.decision_log().count(), 6);
    }

    #[test]
    fn quantum_preempts_streaming_job_for_starved_tenant() {
        let mut c = core(Policy::Quantum); // Ultra96: 3 regions
        // Tenant 0 streams: three long pinned requests fill the fabric.
        for j in 0..3 {
            c.submit(0, j, "mandelbrot", 100, Some("mandelbrot_v1")).unwrap();
        }
        c.begin_round_at(0);
        let mut dispatched = Vec::new();
        while let Some(d) = c.next_decision() {
            let lat = c.service_ns(&d, c.busy_anchors().saturating_sub(1));
            c.mark_running(&d, 0, lat);
            dispatched.push(d);
        }
        assert_eq!(dispatched.len(), 3);
        assert_eq!(c.running_count(), 3);

        // A starved tenant arrives well past the quantum: its failed
        // placement checkpoints the longest-running stream instead of
        // deferring forever.
        c.submit(1, 10, "sobel", 2, Some("sobel_v1")).unwrap();
        c.begin_round_at(50_000_000);
        let p = c.next_decision().unwrap();
        assert_eq!(p.kind, DecisionKind::Preempt);
        assert_eq!(p.user, 0);
        assert!(p.tiles > 0 && p.tiles < 100, "partial progress expected: {p:?}");
        let ck = c.checkpoint(p.ckpt.unwrap()).unwrap();
        assert_eq!(ck.tiles_done + p.tiles, 100, "no lost or duplicated tiles");
        assert!(!c.regions().get(p.anchor).busy, "preempted span is idle");

        // Same round: the starved tenant lands on the freed span.
        let d = c.next_decision().unwrap();
        assert_eq!((d.user, d.anchor, d.kind), (1, p.anchor, DecisionKind::Run));
        let lat = c.service_ns(&d, c.busy_anchors().saturating_sub(1));
        c.mark_running(&d, 50_000_000, 50_000_000 + lat);
        // The victim's remainder cannot place (fabric full again) and
        // must not preempt the short tenant inside its quantum.
        assert!(c.next_decision().is_none());
        assert_eq!(c.counters().preemptions, 1);

        // The short job completes; the remainder resumes, consuming the
        // checkpoint and paying checkpoint + restore in its service.
        c.complete(d.anchor);
        c.begin_round_at(60_000_000);
        let r = c.next_decision().unwrap();
        assert_eq!(r.kind, DecisionKind::Resume);
        assert_eq!((r.user, r.tiles), (0, p.tiles));
        assert_eq!(r.ckpt, p.ckpt);
        assert!(c.checkpoint(p.ckpt.unwrap()).is_none(), "checkpoint consumed");
        assert_eq!(c.counters().resumes, 1);
        let plain = Decision { kind: DecisionKind::Run, ckpt: None, ..r };
        assert!(
            c.service_ns(&r, 0) > c.service_ns(&plain, 0),
            "resume must carry checkpoint/restore overhead"
        );
    }

    #[test]
    fn elastic_pre_rebalances_replicas_for_starved_tenant() {
        let mut c = core(Policy::ElasticPreempt);
        // Tenant 0 replicates a long backlog over the whole fabric.
        for j in 0..3 {
            c.submit(0, j, "mandelbrot", 50, Some("mandelbrot_v1")).unwrap();
        }
        c.begin_round_at(0);
        let mut placed = 0;
        while let Some(d) = c.next_decision() {
            let lat = c.service_ns(&d, c.busy_anchors().saturating_sub(1));
            c.mark_running(&d, 0, lat);
            placed += 1;
        }
        assert_eq!(placed, 3);
        // A starved tenant takes one replica — never a user's last span.
        c.submit(1, 9, "sobel", 1, Some("sobel_v1")).unwrap();
        c.begin_round_at(10_000_000);
        let p = c.next_decision().unwrap();
        assert_eq!((p.kind, p.user), (DecisionKind::Preempt, 0));
        let d = c.next_decision().unwrap();
        assert_eq!((d.user, d.kind), (1, DecisionKind::Run));
        // Plain elastic never preempts: same setup, no Preempt decision.
        let mut c2 = core(Policy::Elastic);
        for j in 0..3 {
            c2.submit(0, j, "mandelbrot", 50, Some("mandelbrot_v1")).unwrap();
        }
        c2.begin_round_at(0);
        while let Some(d) = c2.next_decision() {
            let lat = c2.service_ns(&d, c2.busy_anchors().saturating_sub(1));
            c2.mark_running(&d, 0, lat);
        }
        c2.submit(1, 9, "sobel", 1, Some("sobel_v1")).unwrap();
        c2.begin_round_at(10_000_000);
        assert!(c2.next_decision().is_none());
        assert_eq!(c2.counters().preemptions, 0);
        assert_eq!(c2.counters().skips, 1);
    }

    #[test]
    fn unknown_variant_from_policy_is_rejected_not_fatal() {
        struct BadPolicy;
        impl SchedPolicy for BadPolicy {
            fn name(&self) -> &'static str {
                "bad"
            }
            fn place(
                &mut self,
                _r: &RegionMap,
                _c: &CostModel,
                q: &PlaceReq,
            ) -> Option<Placement> {
                // The accelerator's own symbol is a valid `Sym` that is
                // never one of its variant symbols — a variant the
                // catalog does not know.
                Some(Placement { anchor: 0, variant: q.accel_sym, reconfigure: true })
            }
        }
        let mut c = core(Policy::Elastic);
        c.register_policy(Box::new(BadPolicy));
        assert!(c.set_user_policy(0, "bad"));
        c.submit(0, 7, "vadd", 1, None).unwrap();
        c.begin_round();
        assert!(c.next_decision().is_none(), "rejected, not dispatched");
        let rejected = c.take_rejected();
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].0.job, 7);
        assert!(rejected[0].1.contains("unknown variant"), "{}", rejected[0].1);
        assert!(!c.has_pending());
        assert!(c.take_rejected().is_empty(), "drained once");
    }

    #[test]
    fn builtin_policy_names_route() {
        let mut c = core(Policy::Elastic);
        for name in ["elastic", "fixed", "quantum", "elastic-pre", "fair"] {
            assert!(c.set_user_policy(0, name), "{name} must be registered");
            assert_eq!(c.policy_name_of(0), name);
        }
        assert!(!c.set_user_policy(0, "themis"));
    }

    #[test]
    fn fair_share_caps_spans_at_weighted_share() {
        let mut c = core(Policy::FairShare); // Ultra96: 3 regions
        c.set_tenant_weight(0, 1);
        c.set_tenant_weight(1, 2);
        // Caps under contention: tenant 0 -> ceil(3*1/3) = 1 span,
        // tenant 1 -> ceil(3*2/3) = 2 spans.
        for j in 0..3 {
            c.submit(0, j, "sobel", 2, Some("sobel_v1")).unwrap();
            c.submit(1, 10 + j, "dct", 2, Some("dct_v1")).unwrap();
        }
        c.begin_round_at(0);
        let mut users = Vec::new();
        while let Some(d) = c.next_decision() {
            let lat = c.service_ns(&d, c.busy_anchors().saturating_sub(1));
            c.mark_running(&d, 0, lat);
            users.push(d.user);
        }
        assert_eq!(
            users,
            vec![0, 1, 1],
            "weighted caps must split the 3 regions 1:2 across the tenants"
        );
        // Per-tenant counters track admission and (on completion) the
        // registered running records.
        assert_eq!(c.tenant_counters()[&0].admitted, 3);
        assert_eq!(c.tenant_counters()[&1].admitted, 3);
        for a in 0..3 {
            if c.regions().get(a).busy {
                c.complete(a);
            }
        }
        assert_eq!(
            c.tenant_counters()[&0].completed + c.tenant_counters()[&1].completed,
            3
        );
    }

    #[test]
    fn fair_share_preempts_for_fully_starved_tenant() {
        let mut c = core(Policy::FairShare);
        // A lone tenant fills the fabric (no cap without contention).
        for j in 0..3 {
            c.submit(0, j, "mandelbrot", 100, Some("mandelbrot_v1")).unwrap();
        }
        c.begin_round_at(0);
        let mut placed = 0;
        while let Some(d) = c.next_decision() {
            let lat = c.service_ns(&d, c.busy_anchors().saturating_sub(1));
            c.mark_running(&d, 0, lat);
            placed += 1;
        }
        assert_eq!(placed, 3, "lone tenant must use the whole fabric");
        // A starved tenant past min_run_ns checkpoints the holder and
        // lands on the freed span in the same round.
        c.submit(1, 9, "sobel", 1, Some("sobel_v1")).unwrap();
        c.begin_round_at(15_000_000);
        let p = c.next_decision().unwrap();
        assert_eq!((p.kind, p.user, p.tenant), (DecisionKind::Preempt, 0, 0));
        let d = c.next_decision().unwrap();
        assert_eq!((d.user, d.tenant, d.kind), (1, 1, DecisionKind::Run));
        assert_eq!(c.tenant_counters()[&0].preempted, 1);
    }

    #[test]
    fn decisions_carry_tenant_tags() {
        let mut c = core(Policy::Elastic);
        // submit() accounts to tenant == user; submit_for() separates
        // the scheduler slot from the QoS identity.
        c.submit(0, 0, "vadd", 1, None).unwrap();
        c.submit_for(1, 7, 1, "sobel", 1, None).unwrap();
        c.begin_round();
        let mut tags = Vec::new();
        while let Some(d) = c.next_decision() {
            tags.push((d.user, d.tenant));
        }
        assert_eq!(tags, vec![(0, 0), (1, 7)]);
        assert_eq!(c.tenant_counters()[&7].admitted, 1);
    }

    #[test]
    fn decision_log_ring_wrap_boundary() {
        // The wrap boundary of the ring-capped log: exactly at the cap
        // nothing drops; one past it the oldest entry (and only it)
        // drops; tail queries stay exact across the wrap.
        let mut c = core(Policy::Elastic);
        c.set_log_cap(4);
        for j in 0..4u64 {
            c.submit(0, j, "vadd", 1, None).unwrap();
            c.begin_round();
            let d = c.next_decision().unwrap();
            c.complete(d.anchor);
        }
        assert_eq!(c.decision_log().count(), 4, "at the cap: nothing dropped");
        assert_eq!(c.decisions_dropped(), 0);
        for j in 4..6u64 {
            c.submit(0, j, "vadd", 1, None).unwrap();
            c.begin_round();
            let d = c.next_decision().unwrap();
            c.complete(d.anchor);
        }
        assert_eq!(c.decision_log().count(), 4);
        assert_eq!(c.decisions_dropped(), 2);
        let jobs: Vec<u64> = c.decision_log().map(|d| d.job).collect();
        assert_eq!(jobs, vec![2, 3, 4, 5], "oldest dropped first");
        // Tail positioning at the boundary: n == len, n > len, 1, 0.
        let tail = |c: &SchedCore, n: usize| -> Vec<u64> {
            c.decision_log_tail(n).map(|d| d.job).collect()
        };
        assert_eq!(tail(&c, 4), vec![2, 3, 4, 5]);
        assert_eq!(tail(&c, 9), vec![2, 3, 4, 5], "over-long tail = whole ring");
        assert_eq!(tail(&c, 1), vec![5]);
        assert_eq!(tail(&c, 0), Vec::<u64>::new());
        // Shrinking the cap below the live length drops the oldest.
        c.set_log_cap(2);
        assert_eq!(tail(&c, 9), vec![4, 5]);
        assert_eq!(c.decisions_dropped(), 4);
    }

    #[test]
    fn rollback_failed_dispatch_restores_request_and_regions() {
        let mut c = core(Policy::Elastic);
        c.submit(0, 3, "sobel", 2, Some("sobel_v1")).unwrap();
        c.begin_round();
        let d = c.next_decision().unwrap();
        assert!(d.reconfigure);
        let lat = c.service_ns(&d, 0);
        c.mark_running(&d, 0, lat);
        let req = c.rollback_failed_dispatch(&d);
        assert_eq!((req.user, req.job, req.tiles), (0, 3, 2));
        assert_eq!(req.pin.map(|p| c.resolve(p)), Some("sobel_v1"), "pin survives the rollback");
        assert!(req.resume.is_none());
        assert_eq!(c.running_count(), 0, "running record dropped");
        assert!(!c.regions().get(d.anchor).busy);
        assert!(
            c.regions().get(d.anchor).loaded.is_none(),
            "phantom module must be forgotten"
        );
        // Re-injected, the request dispatches again with a fresh load.
        c.inject(req);
        c.begin_round();
        let d2 = c.next_decision().unwrap();
        assert!(d2.reconfigure);
        assert_eq!(d2.job, 3);
    }

    #[test]
    fn failover_drain_checkpoints_and_migrates_progress() {
        let mut c = core(Policy::Quantum);
        c.submit(0, 0, "mandelbrot", 100, Some("mandelbrot_v1")).unwrap();
        c.begin_round_at(0);
        let d = c.next_decision().unwrap();
        let lat = c.service_ns(&d, 0);
        c.mark_running(&d, 0, lat);
        let drained = c.drain_running_for_failover(lat / 2, true);
        assert_eq!(drained.len(), 1);
        let f = &drained[0];
        assert_eq!(f.decision.kind, DecisionKind::Preempt);
        assert!(f.decision.ckpt.is_none(), "target shard assigns the id");
        assert!(f.done > 0 && f.done < 100, "mid-run progress expected: {f:?}");
        let ck = f.checkpoint.unwrap();
        assert_eq!(ck.tiles_done + f.request.tiles, 100, "no lost or duplicated tiles");
        assert!(f.lost_ns > 0, "setup + partial tile are lost");
        assert!(f.lost_ns < lat, "most of the run is preserved");
        assert_eq!(c.running_count(), 0);
        assert!(!c.regions().get(f.anchor).busy);
        // The remainder resumes on ANOTHER shard via adoption.
        let mut other = core(Policy::Quantum);
        let id = other.adopt_checkpoint(ck);
        let mut req = f.request;
        req.resume = Some(id);
        other.inject(req);
        other.begin_round_at(0);
        let r = other.next_decision().unwrap();
        assert_eq!(r.kind, DecisionKind::Resume);
        assert_eq!(r.ckpt, Some(id));
        assert_eq!(r.tiles, 100 - ck.tiles_done);
        // Drop-and-resubmit baseline: no progress survives.
        let mut c2 = core(Policy::Quantum);
        c2.submit(0, 0, "mandelbrot", 100, Some("mandelbrot_v1")).unwrap();
        c2.begin_round_at(0);
        let d2 = c2.next_decision().unwrap();
        let lat2 = c2.service_ns(&d2, 0);
        c2.mark_running(&d2, 0, lat2);
        let resub = c2.drain_running_for_failover(lat2 / 2, false);
        assert_eq!(resub[0].done, 0);
        assert!(resub[0].checkpoint.is_none());
        assert_eq!(resub[0].request.tiles, 100, "whole dispatch re-runs");
        assert!(resub[0].lost_ns >= f.lost_ns, "resubmit loses at least as much work");
    }

    #[test]
    fn transient_run_failure_requeues_for_rerun() {
        let mut c = core(Policy::Elastic);
        c.submit(0, 9, "sobel", 4, Some("sobel_v1")).unwrap();
        c.begin_round_at(0);
        let d = c.next_decision().unwrap();
        let lat = c.service_ns(&d, 0);
        c.mark_running(&d, 0, lat);
        let lost = c.fail_running(d.anchor, lat).unwrap();
        assert_eq!(lost, lat, "the whole dispatch's work is lost");
        assert!(!c.regions().get(d.anchor).busy);
        assert!(c.regions().get(d.anchor).loaded.is_some(), "module stays resident");
        assert_eq!(c.pending(), 1, "request requeued for a clean re-run");
        c.begin_round_at(lat);
        let d2 = c.next_decision().unwrap();
        assert_eq!((d2.job, d2.tiles, d2.kind), (9, 4, DecisionKind::Run));
        assert!(!d2.reconfigure, "the resident module is reused for the re-run");
        // Nothing at an idle anchor: no-op.
        assert!(c.fail_running(2, 0).is_none());
    }
}
