//! Workload descriptions for the scheduler: who submits what, when.

use super::SimTime;

/// One job: a user's data-parallel acceleration call (Listing 4/5's
/// `jobs` vector).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub user: usize,
    pub accel: String,
    /// Arrival time (virtual ns).
    pub arrival: SimTime,
    /// How many independent acceleration requests the application
    /// exposed (its chosen degree of parallelism, §4.4.2).
    pub requests: usize,
    /// Work items (tiles) per request: total work = requests x tiles.
    pub tiles_per_request: usize,
    /// Pin a specific implementation variant (None = let the scheduler
    /// pick — the resource-elastic default). The Fig 20/21/22 workloads
    /// pin the 1-region variants, matching the paper's setup where the
    /// parallelism sweep replicates a fixed module.
    pub pin_variant: Option<String>,
}

impl JobSpec {
    /// A frame of `total_tiles` chopped into `requests` equal requests
    /// (the paper's image-chopping example; remainder spread over the
    /// first requests).
    pub fn frame(
        user: usize,
        accel: &str,
        arrival: SimTime,
        total_tiles: usize,
        requests: usize,
    ) -> Vec<JobSpec> {
        // Uneven chop: the first (total % requests) requests get one
        // extra tile. Represent as up to two JobSpecs for compactness.
        let base = total_tiles / requests;
        let extra = total_tiles % requests;
        let mut out = Vec::new();
        if extra > 0 {
            out.push(JobSpec {
                user,
                accel: accel.to_string(),
                arrival,
                requests: extra,
                tiles_per_request: base + 1,
                pin_variant: None,
            });
        }
        if requests - extra > 0 && base > 0 {
            out.push(JobSpec {
                user,
                accel: accel.to_string(),
                arrival,
                requests: requests - extra,
                tiles_per_request: base,
                pin_variant: None,
            });
        }
        out
    }

    /// A long-running/streaming job: ONE request carrying `tiles` work
    /// items.  Under cooperative run-to-completion scheduling such a
    /// job monopolises its module for the whole run (the §4.4
    /// time-domain stressor); the preemptive policies (`quantum`,
    /// `elastic-pre`) checkpoint it instead.  `variant` pins an
    /// implementation (usually the 1-region one) so the stream does not
    /// also grab a multi-region span.
    pub fn stream(
        user: usize,
        accel: &str,
        variant: Option<&str>,
        arrival: SimTime,
        tiles: usize,
    ) -> JobSpec {
        JobSpec {
            user,
            accel: accel.to_string(),
            arrival,
            requests: 1,
            tiles_per_request: tiles.max(1),
            pin_variant: variant.map(str::to_string),
        }
    }

    /// Same as [`JobSpec::frame`] but pinned to one variant.
    pub fn frame_pinned(
        user: usize,
        accel: &str,
        variant: &str,
        arrival: SimTime,
        total_tiles: usize,
        requests: usize,
    ) -> Vec<JobSpec> {
        let mut jobs = Self::frame(user, accel, arrival, total_tiles, requests);
        for j in &mut jobs {
            j.pin_variant = Some(variant.to_string());
        }
        jobs
    }
}

/// A full scenario.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub jobs: Vec<JobSpec>,
}

impl Workload {
    pub fn new() -> Workload {
        Workload::default()
    }

    pub fn push(&mut self, job: JobSpec) -> &mut Self {
        self.jobs.push(job);
        self
    }

    pub fn users(&self) -> usize {
        self.jobs.iter().map(|j| j.user + 1).max().unwrap_or(0)
    }

    pub fn total_requests(&self) -> usize {
        self.jobs.iter().map(|j| j.requests).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_chopping_conserves_tiles() {
        for (total, reqs) in [(12, 1), (12, 3), (12, 5), (7, 3), (4, 8)] {
            let jobs = JobSpec::frame(0, "sobel", 0, total, reqs);
            let tiles: usize =
                jobs.iter().map(|j| j.requests * j.tiles_per_request).sum();
            assert_eq!(tiles, total, "total={total} reqs={reqs}");
            let n: usize = jobs.iter().map(|j| j.requests).sum();
            assert_eq!(n, reqs.min(total).max(reqs.min(total)), "reqs clamp");
        }
    }

    #[test]
    fn stream_is_one_request() {
        let j = JobSpec::stream(2, "mandelbrot", Some("mandelbrot_v1"), 5, 400);
        assert_eq!(j.requests, 1);
        assert_eq!(j.tiles_per_request, 400);
        assert_eq!(j.pin_variant.as_deref(), Some("mandelbrot_v1"));
        // Degenerate stream still carries one tile.
        assert_eq!(JobSpec::stream(0, "vadd", None, 0, 0).tiles_per_request, 1);
    }

    #[test]
    fn workload_stats() {
        let mut w = Workload::new();
        for j in JobSpec::frame(0, "sobel", 0, 12, 3) {
            w.push(j);
        }
        for j in JobSpec::frame(1, "mandelbrot", 100, 12, 4) {
            w.push(j);
        }
        assert_eq!(w.users(), 2);
        assert_eq!(w.total_requests(), 7);
    }
}
