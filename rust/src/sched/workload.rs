//! Workload descriptions for the scheduler: who submits what, when —
//! and, for admission-aware scenarios, each tenant's QoS class.

use super::admission::QosClass;
use super::SimTime;

/// One job: a user's data-parallel acceleration call (Listing 4/5's
/// `jobs` vector).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub user: usize,
    pub accel: String,
    /// Arrival time (virtual ns).
    pub arrival: SimTime,
    /// How many independent acceleration requests the application
    /// exposed (its chosen degree of parallelism, §4.4.2).
    pub requests: usize,
    /// Work items (tiles) per request: total work = requests x tiles.
    pub tiles_per_request: usize,
    /// Pin a specific implementation variant (None = let the scheduler
    /// pick — the resource-elastic default). The Fig 20/21/22 workloads
    /// pin the 1-region variants, matching the paper's setup where the
    /// parallelism sweep replicates a fixed module.
    pub pin_variant: Option<String>,
}

impl JobSpec {
    /// A frame of `total_tiles` chopped into `requests` equal requests
    /// (the paper's image-chopping example; remainder spread over the
    /// first requests).
    pub fn frame(
        user: usize,
        accel: &str,
        arrival: SimTime,
        total_tiles: usize,
        requests: usize,
    ) -> Vec<JobSpec> {
        // Uneven chop: the first (total % requests) requests get one
        // extra tile. Represent as up to two JobSpecs for compactness.
        let base = total_tiles / requests;
        let extra = total_tiles % requests;
        let mut out = Vec::new();
        if extra > 0 {
            out.push(JobSpec {
                user,
                accel: accel.to_string(),
                arrival,
                requests: extra,
                tiles_per_request: base + 1,
                pin_variant: None,
            });
        }
        if requests - extra > 0 && base > 0 {
            out.push(JobSpec {
                user,
                accel: accel.to_string(),
                arrival,
                requests: requests - extra,
                tiles_per_request: base,
                pin_variant: None,
            });
        }
        out
    }

    /// A long-running/streaming job: ONE request carrying `tiles` work
    /// items.  Under cooperative run-to-completion scheduling such a
    /// job monopolises its module for the whole run (the §4.4
    /// time-domain stressor); the preemptive policies (`quantum`,
    /// `elastic-pre`) checkpoint it instead.  `variant` pins an
    /// implementation (usually the 1-region one) so the stream does not
    /// also grab a multi-region span.
    pub fn stream(
        user: usize,
        accel: &str,
        variant: Option<&str>,
        arrival: SimTime,
        tiles: usize,
    ) -> JobSpec {
        JobSpec {
            user,
            accel: accel.to_string(),
            arrival,
            requests: 1,
            tiles_per_request: tiles.max(1),
            pin_variant: variant.map(str::to_string),
        }
    }

    /// Same as [`JobSpec::frame`] but pinned to one variant.
    pub fn frame_pinned(
        user: usize,
        accel: &str,
        variant: &str,
        arrival: SimTime,
        total_tiles: usize,
        requests: usize,
    ) -> Vec<JobSpec> {
        let mut jobs = Self::frame(user, accel, arrival, total_tiles, requests);
        for j in &mut jobs {
            j.pin_variant = Some(variant.to_string());
        }
        jobs
    }
}

/// A full scenario.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub jobs: Vec<JobSpec>,
    /// Per-tenant QoS classes (tenant = user in the simulator), applied
    /// to the admission pipeline and the core's tenant weights by
    /// [`super::simulate`] / [`super::simulate_cluster`].  Tenants
    /// without an entry get the permissive default.
    pub qos: Vec<(usize, QosClass)>,
}

impl Workload {
    pub fn new() -> Workload {
        Workload::default()
    }

    /// The multi-tenant cluster mix (fig22's concurrent-tenant scenario
    /// scaled out for fig23): `tenants` users, tenant `t` driving
    /// accelerator `t % 8` from a fixed 8-accelerator rotation, each
    /// submitting `waves` request batches of `reqs_per_wave` x
    /// `tiles_per_req` tiles, with submissions staggered `stagger_ns`
    /// apart (wave-major, tenant-minor order).  The stagger is what
    /// makes board placement interesting: requests arrive while earlier
    /// ones are resident, so a locality-aware policy can route to warm
    /// boards while round-robin scatters every accelerator over every
    /// board.
    pub fn cluster_mix(
        tenants: usize,
        waves: usize,
        reqs_per_wave: usize,
        tiles_per_req: usize,
        stagger_ns: SimTime,
    ) -> Workload {
        const ACCELS: [&str; 8] =
            ["mandelbrot", "sobel", "dct", "fir", "vadd", "histogram", "mm", "black_scholes"];
        let mut w = Workload::new();
        for wave in 0..waves {
            for t in 0..tenants {
                w.push(JobSpec {
                    user: t,
                    accel: ACCELS[t % ACCELS.len()].to_string(),
                    arrival: ((wave * tenants + t) as SimTime) * stagger_ns,
                    requests: reqs_per_wave,
                    tiles_per_request: tiles_per_req,
                    pin_variant: None,
                });
            }
        }
        w
    }

    /// Set (or replace) one tenant's QoS class.
    pub fn set_qos(&mut self, user: usize, qos: QosClass) -> &mut Self {
        self.qos.retain(|(u, _)| *u != user);
        self.qos.push((user, qos));
        self
    }

    /// Give every tenant of the workload the same QoS class — the
    /// uniform-quota knob the fig24 per-RPC baseline uses
    /// (`max_inflight = 1` models a strictly blocking submit→wait
    /// client).
    pub fn with_uniform_qos(mut self, qos: QosClass) -> Workload {
        for u in 0..self.users() {
            self.set_qos(u, qos);
        }
        self
    }

    /// The adversarial admission mix (the no-starvation scenario):
    /// `streamers` tenants each submit one long pinned streaming
    /// request of `stream_tiles` work items, and the remaining
    /// `tenants - streamers` tenants each submit `shorts` short
    /// requests of `short_tiles`.  Everything arrives at t=0 in tenant
    /// order, so neither arrival spacing nor luck spreads the load —
    /// any fairness the short tenants see must come from the admission
    /// pipeline's weighted DRR / quotas and the scheduling policy
    /// (FairShare preemption), which is exactly what the fig24 bench
    /// and the starvation property test measure.
    pub fn tenant_mix(
        tenants: usize,
        streamers: usize,
        stream_tiles: usize,
        shorts: usize,
        short_tiles: usize,
    ) -> Workload {
        let streamers = streamers.min(tenants);
        let mut w = Workload::new();
        for t in 0..tenants {
            if t < streamers {
                w.push(JobSpec::stream(
                    t,
                    "mandelbrot",
                    Some("mandelbrot_v1"),
                    0,
                    stream_tiles,
                ));
            } else {
                for j in JobSpec::frame_pinned(
                    t,
                    "sobel",
                    "sobel_v1",
                    0,
                    shorts * short_tiles,
                    shorts,
                ) {
                    w.push(j);
                }
            }
        }
        w
    }

    pub fn push(&mut self, job: JobSpec) -> &mut Self {
        self.jobs.push(job);
        self
    }

    pub fn users(&self) -> usize {
        self.jobs.iter().map(|j| j.user + 1).max().unwrap_or(0)
    }

    pub fn total_requests(&self) -> usize {
        self.jobs.iter().map(|j| j.requests).sum()
    }
}

/// Collect job streams straight into a workload — what the scenario
/// generators lower through instead of a manual re-push loop.
impl FromIterator<JobSpec> for Workload {
    fn from_iter<I: IntoIterator<Item = JobSpec>>(iter: I) -> Workload {
        Workload { jobs: iter.into_iter().collect(), qos: Vec::new() }
    }
}

impl Extend<JobSpec> for Workload {
    fn extend<I: IntoIterator<Item = JobSpec>>(&mut self, iter: I) {
        self.jobs.extend(iter);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_chopping_conserves_tiles() {
        for (total, reqs) in [(12, 1), (12, 3), (12, 5), (7, 3), (4, 8)] {
            let jobs = JobSpec::frame(0, "sobel", 0, total, reqs);
            let tiles: usize =
                jobs.iter().map(|j| j.requests * j.tiles_per_request).sum();
            assert_eq!(tiles, total, "total={total} reqs={reqs}");
            let n: usize = jobs.iter().map(|j| j.requests).sum();
            assert_eq!(n, reqs.min(total).max(reqs.min(total)), "reqs clamp");
        }
    }

    #[test]
    fn stream_is_one_request() {
        let j = JobSpec::stream(2, "mandelbrot", Some("mandelbrot_v1"), 5, 400);
        assert_eq!(j.requests, 1);
        assert_eq!(j.tiles_per_request, 400);
        assert_eq!(j.pin_variant.as_deref(), Some("mandelbrot_v1"));
        // Degenerate stream still carries one tile.
        assert_eq!(JobSpec::stream(0, "vadd", None, 0, 0).tiles_per_request, 1);
    }

    #[test]
    fn cluster_mix_shape() {
        let w = Workload::cluster_mix(8, 2, 3, 4, 1000);
        assert_eq!(w.users(), 8);
        assert_eq!(w.jobs.len(), 16);
        assert_eq!(w.total_requests(), 48);
        // Arrivals strictly staggered in wave-major, tenant-minor order.
        for (k, j) in w.jobs.iter().enumerate() {
            assert_eq!(j.arrival, k as SimTime * 1000);
        }
        // Eight distinct accelerators in rotation.
        let accels: std::collections::HashSet<&str> =
            w.jobs.iter().map(|j| j.accel.as_str()).collect();
        assert_eq!(accels.len(), 8);
    }

    #[test]
    fn tenant_mix_shape_and_qos() {
        let w = Workload::tenant_mix(5, 2, 100, 6, 2);
        assert_eq!(w.users(), 5);
        // 2 streams (one request each) + 3 short tenants x 6 requests.
        assert_eq!(w.total_requests(), 2 + 3 * 6);
        assert!(w.jobs.iter().all(|j| j.arrival == 0), "adversarial mix arrives at once");
        let streams = w.jobs.iter().filter(|j| j.accel == "mandelbrot").count();
        assert_eq!(streams, 2);
        // Uniform QoS covers every tenant; set_qos replaces.
        let mut w = w.with_uniform_qos(QosClass::new(1, 1));
        assert_eq!(w.qos.len(), 5);
        assert!(w.qos.iter().all(|(_, q)| q.max_inflight == 1));
        w.set_qos(0, QosClass::new(4, 2));
        assert_eq!(w.qos.len(), 5);
        assert_eq!(w.qos.iter().find(|(u, _)| *u == 0).unwrap().1.weight, 4);
    }

    #[test]
    fn workload_stats() {
        let mut w = Workload::new();
        for j in JobSpec::frame(0, "sobel", 0, 12, 3) {
            w.push(j);
        }
        for j in JobSpec::frame(1, "mandelbrot", 100, 12, 4) {
            w.push(j);
        }
        assert_eq!(w.users(), 2);
        assert_eq!(w.total_requests(), 7);
    }

    #[test]
    fn collect_and_extend() {
        let mut w: Workload = JobSpec::frame(0, "sobel", 0, 12, 3).into_iter().collect();
        assert_eq!(w.total_requests(), 3);
        w.extend(JobSpec::frame(1, "dct", 50, 8, 2));
        assert_eq!(w.users(), 2);
        assert_eq!(w.total_requests(), 5);
        assert!(w.qos.is_empty(), "collect carries jobs only");
    }
}
