//! The resource-elastic scheduler (§4.4) — FOS's headline contribution.
//!
//! Users submit *jobs*; a job is a bag of independent, run-to-completion
//! **acceleration requests** (the data-parallel decomposition the
//! application chose, §4.4.2 — e.g. an image chopped into stripes).
//! The scheduler arbitrates the shell's PR regions between users:
//!
//! - **round-robin across users** at request granularity (cooperative
//!   scheduling: a request runs to completion, then the accelerator is
//!   relinquished, §4.4.3);
//! - **replication**: one user's independent requests fan out over all
//!   free regions;
//! - **replacement**: when adjacent regions are free and the accelerator
//!   has a bigger Pareto-optimal implementation, the scheduler switches
//!   to it (module replacement — the DCT super-linear win of Fig 19);
//! - **reuse**: a region already configured with the right accelerator
//!   is used without reconfiguration (cross-application sharing);
//! - **time-multiplexing** when requests outnumber regions — both
//!   cooperatively (run-to-completion, §4.4.3) and **preemptively**:
//!   the [`Quantum`] and [`Elastic::preemptive`] policies checkpoint a
//!   running request's progress, requeue its remainder and restore it
//!   later (see `sched/ARCHITECTURE.md` for the full lifecycle).
//!
//! ## Architecture: one core, two harnesses
//!
//! All of the above lives in [`core`]: [`SchedCore`], a pure scheduling
//! state machine driven through a pluggable [`SchedPolicy`] trait
//! ([`Elastic`] and [`Fixed`] are the seed implementations).  Two
//! harnesses consume it:
//!
//! - [`simulate`] — a virtual-time discrete-event engine: latencies
//!   come from the manifest cycle models (compute), the memsim DDR
//!   model (DMA) and the reconfig PCAP model (partial loads), all
//!   bundled in the shared [`CostModel`].  Real PJRT compute can be
//!   attached ([`SimConfig::executor`]) so results are genuinely
//!   produced — virtual time stays independent of host speed.
//! - the live daemon ([`crate::daemon::Daemon`]) — the same core
//!   drives real partial reconfigurations and PJRT executions, with a
//!   virtual clock mirroring the simulator so both paths make (and
//!   log) identical decision sequences for identical traces.
//!
//! In front of the core sits the **admission pipeline** ([`admission`]):
//! per-tenant bounded queues with structured `Busy` backpressure,
//! weighted deficit-round-robin batched ingest, and token-bucket
//! in-flight quotas — driven by both harnesses at the same point of
//! the round lifecycle, so tenant-level QoS never breaks sim/daemon
//! decision parity (see `sched/ARCHITECTURE.md`, *Admission & QoS*).
//!
//! Above the per-board core sits the **cluster layer** ([`cluster`]):
//! a [`ClusterCore`] owns one scheduler shard per board (heterogeneous
//! mixes welcome) and a pluggable [`PlacementPolicy`] —
//! [`RoundRobin`], [`LeastLoaded`], [`Locality`] — routes every
//! request to a board, with work stealing rebalancing idle shards.
//! [`simulate_cluster`] and the multi-fabric daemon drive it through
//! the same two-harness discipline (see `sched/ARCHITECTURE.md`).

pub mod admission;
pub mod cluster;
pub mod core;
pub mod faults;
pub mod scenario;
mod sim;
mod workload;

pub use self::core::{
    Checkpoint, CostModel, Decision, DecisionKind, Elastic, FailoverDrain, FairShare, Fixed,
    LoadedModule, PlaceReq, Placement, Policy, Quantum, Region, RegionMap, Request, RunningSnap,
    SchedCore, SchedCounters, SchedPolicy, Sym, SymbolTable, TenantSchedCounters, PREEMPT_TICK_NS,
};
pub use admission::{
    AdmissionConfig, AdmissionPipeline, AdmitError, AdmitRequest, QosClass, TenantAdmitCounters,
    DEFAULT_ADMIT_QUEUE_CAP, DEFAULT_QUANTUM_TILES,
};
pub use cluster::{
    BoardHealth, ClusterCore, ClusterCounters, DrainedRun, FailDisposition, FailoverReport,
    LeastLoaded, Locality, MovedCkpt, PlacementKind, PlacementPolicy, RetryOutcome, RoundRobin,
    RouteReq, ShardView, DEFAULT_RECONFIG_FAIL_CAP, DEFAULT_STEAL_THRESHOLD,
    RETRY_BACKOFF_BASE_NS,
};
pub use faults::{FaultPlan, Outage};
pub use scenario::{OrderStrategy, Scenario, ScenarioEvent, TICK_JITTER_MAX_NS};
pub use sim::{
    cluster_mean_turnaround_ns, gen_inputs, mean_turnaround_ns, simulate, simulate_cluster,
    BoardSim, ClusterSimConfig, ClusterSimResult, RegionTrace, SimConfig, SimResult, TraceEvent,
};
pub use workload::{JobSpec, Workload};

use std::time::Duration;

/// Virtual nanoseconds.
pub type SimTime = u64;

pub fn to_duration(t: SimTime) -> Duration {
    Duration::from_nanos(t)
}
