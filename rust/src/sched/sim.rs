//! The virtual-time discrete-event engine implementing both policies:
//! FOS resource-elastic scheduling and the fixed-module baseline
//! (Fig 15's comparison).

use super::workload::Workload;
use super::SimTime;
use crate::accel::Catalog;
use crate::memsim::{config_for, DdrModel};
use crate::reconfig::FpgaManager;
use crate::runtime::Executor;
use crate::shell::{Shell, ShellBoard};
use crate::testutil::Rng;
use std::collections::{BinaryHeap, VecDeque};
use std::cmp::Reverse;

/// Scheduling policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// FOS: replication + replacement + reuse + time-mux (§4.4.3).
    Elastic,
    /// Baseline: one fixed 1-region module per user, run-to-completion.
    Fixed,
}

/// Simulation configuration.
pub struct SimConfig {
    pub board: ShellBoard,
    pub policy: Policy,
    /// Attach the PJRT executor to really compute every tile (slower;
    /// virtual time is unaffected). `None` = latency-model only.
    pub executor: Option<Executor>,
    /// Restrict the number of usable PR regions (Fig 19 sweeps the
    /// resources available for acceleration). `None` = all.
    pub region_limit: Option<usize>,
}

impl SimConfig {
    pub fn new(board: ShellBoard, policy: Policy) -> SimConfig {
        SimConfig { board, policy, executor: None, region_limit: None }
    }

    pub fn with_regions(mut self, n: usize) -> SimConfig {
        self.region_limit = Some(n);
        self
    }
}

/// One allocation in the schedule trace (Fig 15's boxes).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub start: SimTime,
    pub end: SimTime,
    pub region: usize,
    pub span: usize,
    pub user: usize,
    pub accel: String,
    pub variant: String,
    pub tiles: usize,
    pub reconfigured: bool,
}

/// Per-region busy time (utilisation reporting).
#[derive(Debug, Clone, Default)]
pub struct RegionTrace {
    pub busy_ns: u64,
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan: SimTime,
    /// Completion time of each job in workload order.
    pub job_completion: Vec<SimTime>,
    /// Completion of each user's *last* job.
    pub user_completion: Vec<SimTime>,
    pub reconfigs: u64,
    pub reuses: u64,
    pub trace: Vec<TraceEvent>,
    pub regions: Vec<RegionTrace>,
    /// FNV checksum over all real outputs (0 when executor is None) —
    /// lets tests assert that elastic vs fixed compute identical data.
    pub output_checksum: u64,
    pub tiles_executed: u64,
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Loaded {
    accel: String,
    variant: String,
    span: usize,
}

#[derive(Debug, Clone)]
struct Region {
    loaded: Option<Loaded>,
    /// Anchor region index if this slot is the tail of a combined span.
    tail_of: Option<usize>,
    busy: bool,
}

#[derive(Debug, Clone)]
struct PendingReq {
    job: usize,
    tiles: usize,
}

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    Arrival(usize),
    /// Completion at anchor region.
    Complete { anchor: usize, job: usize },
}

/// Run a workload under a policy on a board.
pub fn simulate(catalog: &Catalog, workload: &Workload, cfg: &SimConfig) -> SimResult {
    let mut shell = Shell::build(cfg.board);
    if let Some(limit) = cfg.region_limit {
        shell.floorplan.regions.truncate(limit.max(1));
    }
    let ddr = DdrModel::new(config_for(cfg.board));
    let n_regions = shell.region_count();
    let n_users = workload.users();

    // Precompute per-span partial-bitstream reconfig latency.
    let region_bytes = partial_bytes(&shell);
    let reconfig_ns =
        |span: usize| -> u64 { FpgaManager::latency_for(region_bytes * span, true).as_nanos() as u64 };

    let mut regions: Vec<Region> =
        (0..n_regions).map(|_| Region { loaded: None, tail_of: None, busy: false }).collect();
    let mut queues: Vec<VecDeque<PendingReq>> = vec![VecDeque::new(); n_users];
    let mut fixed_home: Vec<Option<usize>> = vec![None; n_users]; // Fixed policy
    let mut jobs_left: Vec<usize> = workload.jobs.iter().map(|j| j.requests).collect();
    let mut result = SimResult {
        makespan: 0,
        job_completion: vec![0; workload.jobs.len()],
        user_completion: vec![0; n_users],
        reconfigs: 0,
        reuses: 0,
        trace: Vec::new(),
        regions: vec![RegionTrace::default(); n_regions],
        output_checksum: 0xcbf29ce484222325,
        tiles_executed: 0,
    };

    let mut heap: BinaryHeap<Reverse<(SimTime, u64, Event)>> = BinaryHeap::new();
    let mut seq = 0u64;
    for (j, job) in workload.jobs.iter().enumerate() {
        heap.push(Reverse((job.arrival, seq, Event::Arrival(j))));
        seq += 1;
    }
    let mut rr = 0usize;
    let mut rng = Rng::new(0xD15);

    while let Some(Reverse((now, s0, ev))) = heap.pop() {
        // Drain every event at this timestamp before dispatching, so
        // simultaneous arrivals see each other (RR fairness at t=0).
        let mut batch = vec![ev];
        let _ = s0;
        while let Some(Reverse((t, _, _))) = heap.peek() {
            if *t != now {
                break;
            }
            let Reverse((_, _, e)) = heap.pop().unwrap();
            batch.push(e);
        }
        for ev in batch {
            match ev {
                Event::Arrival(j) => {
                    let job = &workload.jobs[j];
                    for _ in 0..job.requests {
                        queues[job.user]
                            .push_back(PendingReq { job: j, tiles: job.tiles_per_request });
                    }
                }
                Event::Complete { anchor, job } => {
                    regions[anchor].busy = false;
                    jobs_left[job] -= 1;
                    if jobs_left[job] == 0 {
                        result.job_completion[job] = now;
                        let u = workload.jobs[job].user;
                        result.user_completion[u] = result.user_completion[u].max(now);
                    }
                    result.makespan = result.makespan.max(now);
                }
            }
        }

        // Dispatch as many requests as will place (cooperative
        // run-to-completion), round-robin across users with pending work.
        // A user whose request cannot (or should not) be placed is
        // skipped this round without blocking the others.
        let mut skip: Vec<usize> = Vec::new();
        loop {
            let Some(user) = next_user(&queues, &mut rr, &skip) else { break };
            let req = queues[user].front().cloned().unwrap();
            let accel = catalog
                .get(&workload.jobs[req.job].accel)
                .unwrap_or_else(|| panic!("unknown accel {}", workload.jobs[req.job].accel));

            let pin = workload.jobs[req.job].pin_variant.as_deref();
            // Uncontended per-tile DMA estimate for cost-aware choices.
            let dma_est_ns = ddr.transfer_ns(accel.bytes_in, 0) + ddr.transfer_ns(accel.bytes_out, 0);
            let backlog_tiles: usize = queues[user].iter().map(|r| r.tiles).sum();
            let placement = match cfg.policy {
                Policy::Elastic => place_elastic(
                    &regions,
                    &shell,
                    accel,
                    &queues,
                    pin,
                    backlog_tiles,
                    dma_est_ns,
                    &reconfig_ns,
                ),
                Policy::Fixed => place_fixed(&regions, accel, user, &mut fixed_home),
            };
            let Some((anchor, variant_name, reconfigure)) = placement else {
                skip.push(user);
                continue;
            };

            // Reconfiguration-avoidance (§4.4.3: "the scheduler avoids
            // partial reconfiguration and reuses an accelerator if it is
            // already available on-chip"): if an instance of this
            // accelerator is loaded but busy, pay a reconfiguration only
            // when the user's backlog amortises it — otherwise wait for
            // the busy instance to free up.
            if reconfigure && cfg.policy == Policy::Elastic {
                let instance_busy = regions.iter().any(|r| {
                    r.busy && r.loaded.as_ref().map(|l| l.accel == accel.name).unwrap_or(false)
                });
                if instance_busy {
                    let v = accel.variant(&variant_name).unwrap();
                    let service_ns =
                        (backlog_tiles as f64 * (v.compute_ns() + dma_est_ns)) as u64;
                    if reconfig_ns(v.regions) > service_ns {
                        skip.push(user);
                        continue;
                    }
                }
            }
            queues[user].pop_front();

            let variant = accel.variant(&variant_name).unwrap();
            let span = variant.regions;

            // Mark busy + (re)load.
            if reconfigure {
                // Clear any previous span association of these slots.
                clear_span(&mut regions, anchor, span);
                regions[anchor].loaded =
                    Some(Loaded { accel: accel.name.clone(), variant: variant_name.clone(), span });
                for r in anchor + 1..anchor + span {
                    regions[r].loaded = None;
                    regions[r].tail_of = Some(anchor);
                }
                result.reconfigs += 1;
            } else {
                result.reuses += 1;
            }
            regions[anchor].busy = true;

            // Latency: reconfig + per-tile (DMA + compute).
            let busy_others = regions.iter().filter(|r| r.busy).count().saturating_sub(1);
            let dma_ns = ddr.transfer_ns(accel.bytes_in, busy_others)
                + ddr.transfer_ns(accel.bytes_out, busy_others);
            let per_tile = dma_ns + variant.compute_ns();
            let mut lat = (per_tile * req.tiles as f64) as u64;
            if reconfigure {
                lat += reconfig_ns(span);
            }

            // Real compute, if attached.
            if let Some(exec) = &cfg.executor {
                for _ in 0..req.tiles {
                    let inputs = gen_inputs(accel, &mut rng);
                    let out = exec
                        .execute(&variant_name, inputs)
                        .expect("real compute failed");
                    for buf in &out.outputs {
                        for v in buf {
                            let bits = v.to_bits() as u64;
                            result.output_checksum =
                                (result.output_checksum ^ bits).wrapping_mul(0x100000001b3);
                        }
                    }
                    result.tiles_executed += 1;
                }
            }

            let end = now + lat;
            result.trace.push(TraceEvent {
                start: now,
                end,
                region: anchor,
                span,
                user,
                accel: accel.name.clone(),
                variant: variant_name.clone(),
                tiles: req.tiles,
                reconfigured: reconfigure,
            });
            for t in result.regions[anchor..anchor + span].iter_mut() {
                t.busy_ns += lat;
            }
            heap.push(Reverse((end, seq, Event::Complete { anchor, job: req.job })));
            seq += 1;
        }
    }

    result
}

/// Bytes of a single-region partial bitstream on this shell.
fn partial_bytes(shell: &Shell) -> usize {
    use crate::bitstream::region_frames;
    let dev = &shell.floorplan.device;
    region_frames(dev, &shell.floorplan.regions[0]).len() * crate::bitstream::FRAME_WORDS * 4
}

fn next_user(queues: &[VecDeque<PendingReq>], rr: &mut usize, skip: &[usize]) -> Option<usize> {
    let n = queues.len();
    for k in 0..n {
        let u = (*rr + k) % n;
        if !queues[u].is_empty() && !skip.contains(&u) {
            *rr = (u + 1) % n;
            return Some(u);
        }
    }
    None
}

/// Elastic placement: reuse > replace-with-biggest-fitting > none.
/// Returns (anchor, variant, needs_reconfig).
#[allow(clippy::too_many_arguments)]
fn place_elastic(
    regions: &[Region],
    shell: &Shell,
    accel: &crate::accel::Accelerator,
    queues: &[VecDeque<PendingReq>],
    pin: Option<&str>,
    backlog_tiles: usize,
    dma_est_ns: f64,
    reconfig_ns: &dyn Fn(usize) -> u64,
) -> Option<(usize, String, bool)> {
    // 1. Reuse an idle region already configured with this accelerator
    //    (prefer the biggest loaded variant — it's fastest). Pinned jobs
    //    reuse only their pinned variant.
    let mut best_reuse: Option<(usize, usize)> = None; // (anchor, span)
    for (i, r) in regions.iter().enumerate() {
        if r.busy || r.tail_of.is_some() {
            continue;
        }
        if let Some(l) = &r.loaded {
            if l.accel == accel.name
                && pin.map(|p| p == l.variant).unwrap_or(true)
                && span_idle(regions, i, l.span)
                && best_reuse.map(|(_, s)| l.span > s).unwrap_or(true)
            {
                best_reuse = Some((i, l.span));
            }
        }
    }
    if let Some((anchor, _)) = best_reuse {
        let v = regions[anchor].loaded.as_ref().unwrap().variant.clone();
        return Some((anchor, v, false));
    }

    // 2. Reconfigure free capacity. Multi-region variants only when a
    //    single tenant is active (the paper grows a lone user's share;
    //    under contention every user gets 1-region modules). Among the
    //    variants that fit, pick the one minimising
    //    reconfig + backlog x per-tile — bigger is NOT always better
    //    when the job cannot amortise the larger partial bitstream.
    if let Some(p) = pin {
        let v = accel.variant(p)?;
        let anchor = find_free_span(regions, shell, v.regions)?;
        return Some((anchor, v.name.clone(), true));
    }
    let active_users = queues.iter().filter(|q| !q.is_empty()).count();
    let span_cap = if active_users <= 1 { regions.len() } else { 1 };
    let free_now = regions
        .iter()
        .filter(|r| !r.busy && r.tail_of.is_none())
        .count()
        .max(1);
    let mut best: Option<(u64, usize, String)> = None;
    for v in &accel.variants {
        if v.regions > span_cap {
            continue;
        }
        if let Some(anchor) = find_free_span(regions, shell, v.regions) {
            // Throughput-aware score: assume the backlog will spread
            // over as many replicas of this variant as fit in the
            // currently free capacity (replication), each paying its
            // own reconfiguration.
            let replicas = (free_now / v.regions).max(1) as f64;
            let drain = backlog_tiles as f64 * (v.compute_ns() + dma_est_ns) / replicas;
            let score = reconfig_ns(v.regions) + drain as u64;
            if best.as_ref().map(|(s, _, _)| score < *s).unwrap_or(true) {
                best = Some((score, anchor, v.name.clone()));
            }
        }
    }
    best.map(|(_, anchor, name)| (anchor, name, true))
}

/// Fixed placement: user keeps one region for the whole run.
fn place_fixed(
    regions: &[Region],
    accel: &crate::accel::Accelerator,
    user: usize,
    home: &mut [Option<usize>],
) -> Option<(usize, String, bool)> {
    let v = accel.smallest_variant();
    if let Some(r) = home[user] {
        if regions[r].busy {
            return None; // our module is busy; wait (run-to-completion)
        }
        let needs = regions[r]
            .loaded
            .as_ref()
            .map(|l| l.accel != accel.name || l.variant != v.name)
            .unwrap_or(true);
        return Some((r, v.name.clone(), needs));
    }
    // Claim the first region nobody owns.
    let owned: Vec<usize> = home.iter().flatten().copied().collect();
    let r = (0..regions.len()).find(|r| !owned.contains(r) && !regions[*r].busy)?;
    home[user] = Some(r);
    Some((r, v.name.clone(), true))
}

fn span_idle(regions: &[Region], anchor: usize, span: usize) -> bool {
    if anchor + span > regions.len() {
        return false;
    }
    !regions[anchor..anchor + span].iter().any(|r| r.busy)
        && regions[anchor + 1..anchor + span]
            .iter()
            .all(|r| r.tail_of == Some(anchor))
}

/// First anchor of `span` adjacent, idle, non-tail regions.
fn find_free_span(regions: &[Region], shell: &Shell, span: usize) -> Option<usize> {
    (0..regions.len().saturating_sub(span - 1)).find(|&a| {
        shell.floorplan.combinable(a, span)
            && (a..a + span).all(|r| {
                !regions[r].busy
                    // A tail slot may be cannibalised only with its anchor.
                    && regions[r].tail_of.map(|t| t >= a).unwrap_or(true)
            })
    })
}

/// Detach any span structure overlapping [anchor, anchor+span).
fn clear_span(regions: &mut [Region], anchor: usize, span: usize) {
    // If a slot we take was the tail of an earlier anchor, that loaded
    // module is destroyed.
    for r in anchor..anchor + span {
        if let Some(t) = regions[r].tail_of {
            regions[t].loaded = None;
        }
        regions[r].tail_of = None;
        regions[r].loaded = None;
    }
    // If a later region was a tail of one of ours, detach it too.
    for r in anchor + span..regions.len() {
        if regions[r].tail_of.map(|t| t < anchor + span).unwrap_or(false) {
            regions[r].tail_of = None;
            regions[r].loaded = None;
        }
    }
}

/// Deterministic input generation for real-compute mode.
pub fn gen_inputs(accel: &crate::accel::Accelerator, rng: &mut Rng) -> Vec<Vec<f32>> {
    accel
        .inputs
        .iter()
        .map(|spec| {
            let n = spec.elements();
            match accel.name.as_str() {
                "histogram" => (0..n).map(|_| rng.f32()).collect(),
                "black_scholes" => {
                    // (N, 5) S/K/T/r/sigma columns, all positive.
                    let rows = n / 5;
                    let mut buf = vec![0f32; n];
                    for r in 0..rows {
                        buf[r * 5] = 50.0 + 100.0 * rng.f32();
                        buf[r * 5 + 1] = 50.0 + 100.0 * rng.f32();
                        buf[r * 5 + 2] = 0.1 + 1.9 * rng.f32();
                        buf[r * 5 + 3] = 0.1 * rng.f32();
                        buf[r * 5 + 4] = 0.1 + 0.5 * rng.f32();
                    }
                    buf
                }
                _ => (0..n).map(|_| rng.normal()).collect(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::workload::JobSpec;

    fn catalog() -> Catalog {
        Catalog::load_default().unwrap()
    }

    fn single_user(accel: &str, requests: usize, tiles: usize) -> Workload {
        let mut w = Workload::new();
        for j in JobSpec::frame(0, accel, 0, requests * tiles, requests) {
            w.push(j);
        }
        w
    }

    #[test]
    fn replication_speeds_up_single_user() {
        // Fig 20's core effect: more requests -> more parallelism, until
        // the region count (3 on Ultra96) is reached. Pinned to the
        // 1-region variant, as in the paper's sweep.
        let c = catalog();
        let lat = |reqs: usize| {
            let mut w = Workload::new();
            for j in JobSpec::frame_pinned(0, "mandelbrot", "mandelbrot_v1", 0, 12, reqs) {
                w.push(j);
            }
            simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic)).makespan
        };
        let l1 = lat(1);
        let l3 = lat(3);
        let l6 = lat(6);
        // "Almost linear" (paper §5.5.1): reconfiguration overhead keeps
        // it under a perfect 3x.
        assert!(
            (l1 as f64 / l3 as f64) > 2.3,
            "3 requests should be ~3x faster: {l1} vs {l3}"
        );
        // Past the region count, stagnation (Fig 21): 6 requests buy
        // little over 3.
        assert!((l3 as f64 / l6 as f64) > 0.85, "{l3} vs {l6}");
    }

    #[test]
    fn multiples_of_region_count_win() {
        // 12 tiles on 3 regions: 4 requests (uneven rounds) slower than
        // 6 requests (2 clean rounds of 3)? Paper: multiples of the
        // region count avoid leftover-bottlenecks. With equal total work
        // 3 requests beats 4 requests.
        let c = catalog();
        let w3 = {
            let mut w = Workload::new();
            for j in JobSpec::frame(0, "mandelbrot", 0, 12, 3) {
                w.push(j);
            }
            w
        };
        let w4 = {
            let mut w = Workload::new();
            for j in JobSpec::frame(0, "mandelbrot", 0, 12, 4) {
                w.push(j);
            }
            w
        };
        let cfg = SimConfig::new(ShellBoard::Ultra96, Policy::Elastic);
        let m3 = simulate(&c, &w3, &cfg).makespan;
        let m4 = simulate(&c, &w4, &cfg).makespan;
        assert!(m3 <= m4, "3 reqs {m3} should beat 4 reqs {m4} on 3 regions");
    }

    #[test]
    fn elastic_beats_fixed() {
        // Fig 15: same four single-job users, elastic vs fixed.
        let c = catalog();
        let mut w = Workload::new();
        for (u, arrival) in [(0usize, 0u64), (1, 2_000_000), (2, 4_000_000), (3, 30_000_000)] {
            for j in JobSpec::frame(u, "dct", arrival, 24, 8) {
                w.push(j);
            }
        }
        let el = simulate(&c, &w, &SimConfig::new(ShellBoard::Zcu102, Policy::Elastic));
        let fx = simulate(&c, &w, &SimConfig::new(ShellBoard::Zcu102, Policy::Fixed));
        assert!(
            el.makespan < fx.makespan,
            "elastic {} >= fixed {}",
            el.makespan,
            fx.makespan
        );
        // The elastic run must actually have replicated/reused.
        assert!(el.reuses > 0);
    }

    #[test]
    fn reuse_avoids_reconfiguration() {
        let c = catalog();
        // Two users running the SAME accelerator share it in time.
        let mut w = Workload::new();
        for j in JobSpec::frame(0, "sobel", 0, 6, 6) {
            w.push(j);
        }
        for j in JobSpec::frame(1, "sobel", 0, 6, 6) {
            w.push(j);
        }
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
        // 12 requests, 3 regions: at most a handful of reconfigs, many reuses.
        assert!(r.reconfigs <= 3, "reconfigs {}", r.reconfigs);
        assert_eq!(r.reconfigs + r.reuses, 12);
    }

    #[test]
    fn dct_uses_bigger_variant_when_alone() {
        let c = catalog();
        // Long job (paper-scale): the 2-region variant's extra partial-
        // bitstream cost amortises and replacement kicks in.
        let w = single_user("dct", 2, 200);
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Zcu102, Policy::Elastic));
        assert!(
            r.trace.iter().any(|t| t.variant == "dct_v2"),
            "expected dct_v2 in trace: {:?}",
            r.trace.iter().map(|t| t.variant.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn multi_user_gets_single_region_modules() {
        let c = catalog();
        let mut w = Workload::new();
        for j in JobSpec::frame(0, "dct", 0, 8, 4) {
            w.push(j);
        }
        for j in JobSpec::frame(1, "mandelbrot", 0, 8, 4) {
            w.push(j);
        }
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
        // While both users are active, spans must be 1... the tail of the
        // run (one user drained) may still grow. Check early trace only.
        let early: Vec<_> = r.trace.iter().filter(|t| t.start == 0).collect();
        assert!(!early.is_empty());
        assert!(early.iter().all(|t| t.span == 1), "{early:?}");
        // Round-robin fairness: both users dispatched at t=0.
        let users: std::collections::HashSet<usize> = early.iter().map(|t| t.user).collect();
        assert_eq!(users.len(), 2);
    }

    #[test]
    fn trace_is_consistent() {
        let c = catalog();
        let w = single_user("fir", 6, 2);
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
        assert_eq!(r.trace.len(), 6);
        for t in &r.trace {
            assert!(t.end > t.start);
            assert!(t.region + t.span <= 3);
        }
        // No two events overlap on the same region.
        for (i, a) in r.trace.iter().enumerate() {
            for b in &r.trace[i + 1..] {
                let disjoint_regions =
                    a.region + a.span <= b.region || b.region + b.span <= a.region;
                let disjoint_time = a.end <= b.start || b.end <= a.start;
                assert!(disjoint_regions || disjoint_time, "{a:?} vs {b:?}");
            }
        }
        assert_eq!(r.makespan, r.trace.iter().map(|t| t.end).max().unwrap());
    }

    #[test]
    fn fixed_policy_isolates_users_to_one_region() {
        let c = catalog();
        let mut w = Workload::new();
        for u in 0..2 {
            for j in JobSpec::frame(u, "sobel", 0, 4, 4) {
                w.push(j);
            }
        }
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Fixed));
        let mut per_user: std::collections::HashMap<usize, std::collections::HashSet<usize>> =
            Default::default();
        for t in &r.trace {
            assert_eq!(t.span, 1);
            per_user.entry(t.user).or_default().insert(t.region);
        }
        for (u, regions) in per_user {
            assert_eq!(regions.len(), 1, "user {u} used {regions:?}");
        }
    }
}
