//! The virtual-time discrete-event harness around the shared scheduler
//! core (Fig 15's comparison engine).  All placement intelligence lives
//! in [`super::core::SchedCore`]; this file only owns *time*: it feeds
//! arrivals and completions into the core and turns its [`Decision`]s
//! into trace events, latencies and (optionally) real PJRT compute.

use super::admission::{AdmissionConfig, AdmissionPipeline, AdmitRequest};
use super::cluster::{
    ClusterCore, ClusterCounters, FailDisposition, PlacementKind, DEFAULT_STEAL_THRESHOLD,
};
use super::core::{Decision, DecisionKind, Policy, SchedCore, SchedCounters, TenantSchedCounters};
use super::faults::FaultPlan;
use super::scenario::OrderStrategy;
use super::workload::{JobSpec, Workload};
use super::SimTime;
use crate::accel::Catalog;
use crate::runtime::Executor;
use crate::shell::{Shell, ShellBoard};
use crate::testutil::Rng;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};

/// Simulation configuration.
pub struct SimConfig {
    pub board: ShellBoard,
    pub policy: Policy,
    /// Attach the PJRT executor to really compute every tile (slower;
    /// virtual time is unaffected). `None` = latency-model only.
    pub executor: Option<Executor>,
    /// Restrict the number of usable PR regions (Fig 19 sweeps the
    /// resources available for acceleration). `None` = all.
    pub region_limit: Option<usize>,
    /// Admission-pipeline tuning.  The default is permissive (ingest
    /// drains every queue in tenant order), which reproduces the
    /// pre-pipeline decision sequences exactly; tighten it (and the
    /// workload's [`Workload::qos`] classes) to simulate the daemon's
    /// QoS behaviour — the DES then replays the daemon's batched
    /// ingest decision sequence verbatim (same pipeline code).
    pub admission: AdmissionConfig,
    /// How the DES resolves its nondeterminism points (equal-timestamp
    /// batches, ingest boundaries, tick cadence).  The default
    /// [`OrderStrategy::Identity`] is byte-identical to the fixed FIFO
    /// orderings; seeded strategies are the concurrency fuzzer.
    pub order: OrderStrategy,
}

impl SimConfig {
    pub fn new(board: ShellBoard, policy: Policy) -> SimConfig {
        SimConfig {
            board,
            policy,
            executor: None,
            region_limit: None,
            admission: AdmissionConfig::default(),
            order: OrderStrategy::default(),
        }
    }

    pub fn with_regions(mut self, n: usize) -> SimConfig {
        self.region_limit = Some(n);
        self
    }

    pub fn with_admission(mut self, cfg: AdmissionConfig) -> SimConfig {
        self.admission = cfg;
        self
    }

    pub fn with_order(mut self, order: OrderStrategy) -> SimConfig {
        self.order = order;
        self
    }
}

/// One allocation in the schedule trace (Fig 15's boxes).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub start: SimTime,
    pub end: SimTime,
    pub region: usize,
    pub span: usize,
    pub user: usize,
    pub accel: String,
    pub variant: String,
    pub tiles: usize,
    pub reconfigured: bool,
}

/// Per-region busy time (utilisation reporting).
#[derive(Debug, Clone, Default)]
pub struct RegionTrace {
    pub busy_ns: u64,
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan: SimTime,
    /// Completion time of each job in workload order.
    pub job_completion: Vec<SimTime>,
    /// Completion of each user's *last* job.
    pub user_completion: Vec<SimTime>,
    /// The run's scheduling counters — the same
    /// [`crate::sched::SchedCounters`] the daemon's `DaemonStats`
    /// mirrors on the live path.
    pub counters: SchedCounters,
    pub trace: Vec<TraceEvent>,
    pub regions: Vec<RegionTrace>,
    /// The core's ordered decision log — compared verbatim against the
    /// live daemon's in the sim/daemon parity test.
    pub decisions: Vec<Decision>,
    /// Per-tenant scheduling counters (admitted / completed /
    /// preempted / rejected), tenant id ascending.
    pub per_tenant: Vec<(usize, TenantSchedCounters)>,
    /// Requests deferred by `Busy` backpressure (a request retried
    /// twice counts twice); every deferral is eventually admitted.
    pub busy_retries: u64,
    /// FNV checksum over all real outputs (0 when executor is None) —
    /// lets tests assert that elastic vs fixed compute identical data.
    pub output_checksum: u64,
    pub tiles_executed: u64,
}

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    Arrival(usize),
    /// Re-arrival of a job's remaining requests after a `Busy`
    /// admission rejection — the simulator's model of a client
    /// honouring the retry hint.
    Retry { job: usize, requests: usize },
    /// Completion at anchor region.
    Complete { anchor: usize, job: usize },
    /// Preemption-check round: re-dispatch while users are starved and
    /// work is running, so an expired quantum is observed mid-span.
    Tick,
}

/// Enqueue `count` requests of workload job `j` into the admission
/// pipeline; on `Busy` backpressure, schedule a retry event (built by
/// `retry(job, remaining)` — the single-board and cluster harnesses
/// only differ in their event enum) at the hint's deadline and report
/// how many requests were deferred.
#[allow(clippy::too_many_arguments)]
fn pipeline_enqueue<E: Ord>(
    admit: &mut AdmissionPipeline,
    heap: &mut BinaryHeap<Reverse<(SimTime, u64, E)>>,
    seq: &mut u64,
    now: SimTime,
    j: usize,
    spec: &JobSpec,
    count: usize,
    retry: impl Fn(usize, usize) -> E,
) -> u64 {
    for k in 0..count {
        let r = AdmitRequest {
            user: spec.user,
            tenant: spec.user,
            job: j as u64,
            accel: spec.accel.clone(),
            tiles: spec.tiles_per_request,
            pin: spec.pin_variant.clone(),
        };
        if let Err(e) = admit.enqueue(r) {
            heap.push(Reverse((now + e.retry_after_ns(), *seq, retry(j, count - k))));
            *seq += 1;
            return (count - k) as u64;
        }
    }
    0
}

/// Run a workload under a policy on a board.
pub fn simulate(catalog: &Catalog, workload: &Workload, cfg: &SimConfig) -> SimResult {
    let mut shell = Shell::build(cfg.board);
    if let Some(limit) = cfg.region_limit {
        shell.floorplan.regions.truncate(limit.max(1));
    }
    let n_regions = shell.region_count();
    let n_users = workload.users();

    let mut core = SchedCore::new(&shell, catalog.clone(), cfg.policy);
    // The tenant-aware admission stage (tenant = user in the DES):
    // the same pipeline type the daemon dispatcher drives, at the same
    // point of the round lifecycle, so a QoS-configured simulation
    // reproduces the daemon's batched-ingest decision sequence.
    let mut admit = AdmissionPipeline::new(cfg.admission);
    core.set_bw_partition(cfg.admission.bw_partition);
    for &(u, q) in &workload.qos {
        admit.set_qos(u, q);
        core.set_tenant_weight(u, q.weight);
    }
    let mut jobs_left: Vec<usize> = workload.jobs.iter().map(|j| j.requests).collect();
    let mut result = SimResult {
        makespan: 0,
        job_completion: vec![0; workload.jobs.len()],
        user_completion: vec![0; n_users],
        counters: SchedCounters::default(),
        trace: Vec::new(),
        regions: vec![RegionTrace::default(); n_regions],
        decisions: Vec::new(),
        per_tenant: Vec::new(),
        busy_retries: 0,
        output_checksum: 0xcbf29ce484222325,
        tiles_executed: 0,
    };

    let mut heap: BinaryHeap<Reverse<(SimTime, u64, Event)>> = BinaryHeap::new();
    let mut seq = 0u64;
    for (j, job) in workload.jobs.iter().enumerate() {
        heap.push(Reverse((job.arrival, seq, Event::Arrival(j))));
        seq += 1;
    }
    let mut rng = Rng::new(0xD15);
    // Completion events cancelled by a preemption (by event seq).
    let mut cancelled: HashSet<u64> = HashSet::new();
    // anchor -> seq of the completion event of the dispatch running there.
    let mut running_seq: HashMap<usize, u64> = HashMap::new();
    // anchor -> index of the open trace event of that dispatch, so a
    // preemption can truncate it to the tiles actually completed.
    let mut open_trace: HashMap<usize, usize> = HashMap::new();
    // One pending preemption-check tick at a time (see PREEMPT_TICK_NS).
    let mut next_tick: Option<SimTime> = None;

    while let Some(Reverse((now, s0, ev))) = heap.pop() {
        // Drain every event at this timestamp before dispatching, so
        // simultaneous arrivals see each other (RR fairness at t=0).
        let mut batch = vec![(s0, ev)];
        while let Some(Reverse((t, _, _))) = heap.peek() {
            if *t != now {
                break;
            }
            let Reverse((_, s, e)) = heap.pop().unwrap();
            batch.push((s, e));
        }
        // Ordering-fuzz hook: a seeded strategy processes this
        // equal-timestamp batch in a permuted (but deterministic,
        // time-keyed) order; identity keeps heap order untouched.
        cfg.order.permute_events(now, &mut batch);
        for (s, ev) in batch {
            match ev {
                Event::Arrival(j) => {
                    let job = &workload.jobs[j];
                    core.validate(&job.accel, job.pin_variant.as_deref())
                        .unwrap_or_else(|e| panic!("{e}"));
                    result.busy_retries += pipeline_enqueue(
                        &mut admit,
                        &mut heap,
                        &mut seq,
                        now,
                        j,
                        job,
                        job.requests,
                        |job, requests| Event::Retry { job, requests },
                    );
                }
                Event::Retry { job, requests } => {
                    let spec = &workload.jobs[job];
                    result.busy_retries += pipeline_enqueue(
                        &mut admit,
                        &mut heap,
                        &mut seq,
                        now,
                        job,
                        spec,
                        requests,
                        |job, requests| Event::Retry { job, requests },
                    );
                }
                Event::Tick => {} // only exists to trigger the round below
                Event::Complete { anchor, job } => {
                    if cancelled.remove(&s) {
                        continue; // this dispatch was preempted mid-span
                    }
                    core.complete(anchor);
                    admit.complete(workload.jobs[job].user);
                    if running_seq.get(&anchor) == Some(&s) {
                        running_seq.remove(&anchor);
                        open_trace.remove(&anchor);
                    }
                    jobs_left[job] -= 1;
                    if jobs_left[job] == 0 {
                        result.job_completion[job] = now;
                        let u = workload.jobs[job].user;
                        result.user_completion[u] = result.user_completion[u].max(now);
                    }
                    result.makespan = result.makespan.max(now);
                }
            }
        }

        // Batched ingest: one admission round feeds every eligible
        // queued request (weighted DRR under in-flight quotas) into
        // the scheduler before the dispatch round — the daemon
        // dispatcher's exact rule (plus the ingest-boundary fuzz hook).
        for r in admit.ingest_ordered(&cfg.order, now) {
            core.submit_for(r.user, r.tenant, r.job, &r.accel, r.tiles, r.pin.as_deref())
                .unwrap_or_else(|e| panic!("{e}"));
        }

        // Dispatch as many requests as will place (cooperative
        // run-to-completion); the core round-robins across users and
        // defers anyone whose request cannot (or should not) be placed
        // without blocking the others.
        core.begin_round_at(now);
        while let Some(d) = core.next_decision() {
            if d.kind == DecisionKind::Preempt {
                // The victim's remainder is already requeued by the
                // core; mirror the harness side: cancel its completion
                // event and truncate its trace allocation to the tiles
                // that actually finished before `now`.
                let vseq = running_seq
                    .remove(&d.anchor)
                    .expect("preempt decision without a running dispatch");
                cancelled.insert(vseq);
                if let Some(idx) = open_trace.remove(&d.anchor) {
                    let (old_end, region, span) = {
                        let t = &mut result.trace[idx];
                        let old_end = t.end;
                        t.end = now;
                        t.tiles -= d.tiles; // keep only the completed slice
                        (old_end, t.region, t.span)
                    };
                    for t in result.regions[region..region + span].iter_mut() {
                        t.busy_ns -= old_end - now;
                    }
                }
                continue;
            }

            // Latency: reconfig + per-tile (DMA + compute), contended
            // by the other busy modules; resumes add checkpoint/restore.
            let busy_others = core.busy_anchors().saturating_sub(1);
            let lat = core.service_ns(&d, busy_others);
            core.mark_running(&d, now, now + lat);

            // Real compute, if attached.  Executed eagerly at dispatch:
            // a slice preempted later was still computed in full here,
            // which inflates tiles_executed but never corrupts outputs
            // (re-runs are idempotent; virtual time is unaffected).
            if let Some(exec) = &cfg.executor {
                let accel = catalog.get(core.resolve(d.accel)).unwrap();
                let variant_name = core.resolve(d.variant).to_string();
                for _ in 0..d.tiles {
                    let inputs = gen_inputs(accel, &mut rng);
                    let out = exec.execute(&variant_name, inputs).expect("real compute failed");
                    for buf in &out.outputs {
                        for v in buf {
                            let bits = v.to_bits() as u64;
                            result.output_checksum =
                                (result.output_checksum ^ bits).wrapping_mul(0x100000001b3);
                        }
                    }
                    result.tiles_executed += 1;
                }
            }

            let end = now + lat;
            open_trace.insert(d.anchor, result.trace.len());
            result.trace.push(TraceEvent {
                start: now,
                end,
                region: d.anchor,
                span: d.span,
                user: d.user,
                accel: core.resolve(d.accel).to_string(),
                variant: core.resolve(d.variant).to_string(),
                tiles: d.tiles,
                reconfigured: d.reconfigure,
            });
            for t in result.regions[d.anchor..d.anchor + d.span].iter_mut() {
                t.busy_ns += lat;
            }
            running_seq.insert(d.anchor, seq);
            heap.push(Reverse((
                end,
                seq,
                Event::Complete { anchor: d.anchor, job: d.job as usize },
            )));
            seq += 1;
        }

        // Requests the core rejected instead of dispatching (a policy
        // chose an unknown variant): count them completed-with-failure
        // so the run terminates; built-in policies never trigger this.
        for (req, _reason) in core.take_rejected() {
            admit.complete(req.tenant);
            let j = req.job as usize;
            jobs_left[j] = jobs_left[j].saturating_sub(1);
            if jobs_left[j] == 0 {
                result.job_completion[j] = now;
                let u = workload.jobs[j].user;
                result.user_completion[u] = result.user_completion[u].max(now);
            }
        }

        // Preemption-check cadence (core-owned rule, shared verbatim
        // with the daemon dispatcher): re-round every PREEMPT_TICK_NS
        // while a preemption-capable policy has a starved user and work
        // is running, so expired quanta are observed mid-span.
        if let Some(t) = core.preempt_tick_due(&mut next_tick, now) {
            // The core's `next_tick` bookkeeping keeps the unjittered
            // due time; only the heap event moves (bounded, additive),
            // so a fuzzed tick fires late but never early.
            heap.push(Reverse((cfg.order.jitter_tick(0, t), seq, Event::Tick)));
            seq += 1;
        }
    }

    result.counters = core.counters().clone();
    result.decisions = core.decision_log().copied().collect();
    result.per_tenant = core.tenant_counters().iter().map(|(&t, &c)| (t, c)).collect();
    result
}

/// Mean job turnaround (completion − arrival) over a finished run,
/// in virtual ns — the fig22-style fairness measurement preemption is
/// judged by.
pub fn mean_turnaround_ns(w: &Workload, r: &SimResult) -> f64 {
    mean_turnaround_from(w, &r.job_completion)
}

/// [`mean_turnaround_ns`] over a cluster run.
pub fn cluster_mean_turnaround_ns(w: &Workload, r: &ClusterSimResult) -> f64 {
    mean_turnaround_from(w, &r.job_completion)
}

fn mean_turnaround_from(w: &Workload, job_completion: &[SimTime]) -> f64 {
    if w.jobs.is_empty() {
        return 0.0;
    }
    let sum: u64 = w
        .jobs
        .iter()
        .zip(job_completion)
        .map(|(j, &c)| c.saturating_sub(j.arrival))
        .sum();
    sum as f64 / w.jobs.len() as f64
}

/// Cluster simulation configuration: one shard per entry of `boards`
/// (heterogeneous mixes welcome), every shard running `policy`, with
/// `placement` deciding which board each request lands on.
pub struct ClusterSimConfig {
    pub boards: Vec<ShellBoard>,
    pub policy: Policy,
    pub placement: PlacementKind,
    /// Work-stealing donor threshold (queued tiles).
    pub steal_threshold: usize,
    /// Admission-pipeline tuning (see [`SimConfig::admission`]).
    pub admission: AdmissionConfig,
    /// Deterministic fault injection: board outages, reconfiguration
    /// failures, transient run errors — consumed at the same
    /// round-lifecycle points the daemon's virtual-time loop consumes
    /// the identical plan (fault parity).  `None` = perfect substrate.
    pub faults: Option<FaultPlan>,
    /// `false` switches failover to the drop-and-resubmit baseline
    /// (no checkpointed progress across migration).
    pub checkpoint_migration: bool,
    /// Nondeterminism-resolution strategy (see [`SimConfig::order`]).
    pub order: OrderStrategy,
}

impl ClusterSimConfig {
    pub fn new(
        boards: Vec<ShellBoard>,
        policy: Policy,
        placement: PlacementKind,
    ) -> ClusterSimConfig {
        ClusterSimConfig {
            boards,
            policy,
            placement,
            steal_threshold: DEFAULT_STEAL_THRESHOLD,
            admission: AdmissionConfig::default(),
            faults: None,
            checkpoint_migration: true,
            order: OrderStrategy::default(),
        }
    }

    pub fn with_admission(mut self, cfg: AdmissionConfig) -> ClusterSimConfig {
        self.admission = cfg;
        self
    }

    pub fn with_faults(mut self, plan: FaultPlan) -> ClusterSimConfig {
        self.faults = Some(plan);
        self
    }

    pub fn with_order(mut self, order: OrderStrategy) -> ClusterSimConfig {
        self.order = order;
        self
    }

    /// Use the drop-and-resubmit failover baseline instead of
    /// checkpoint-based migration.
    pub fn with_drop_and_resubmit(mut self) -> ClusterSimConfig {
        self.checkpoint_migration = false;
        self
    }
}

/// One board's slice of a cluster run.
#[derive(Debug, Clone)]
pub struct BoardSim {
    pub board: ShellBoard,
    /// The shard's scheduling counters (per-board reconfig/preemption
    /// accounting — the fig23 comparison material).
    pub counters: SchedCounters,
    /// The shard's ordered decision log — compared verbatim against
    /// the live daemon's per-board log in `tests/cluster_parity.rs`.
    pub decisions: Vec<Decision>,
    /// Region-seconds of busy time across the shard (utilisation).
    pub busy_ns: u64,
}

/// Result of a multi-board cluster run.
#[derive(Debug, Clone)]
pub struct ClusterSimResult {
    pub makespan: SimTime,
    /// Completion time of each job in workload order.
    pub job_completion: Vec<SimTime>,
    /// Per-board counters, decision logs and utilisation.
    pub boards: Vec<BoardSim>,
    /// The merged `(board, decision)` log in global dispatch order.
    pub merged: Vec<(usize, Decision)>,
    /// Routing/stealing counters from the cluster core.
    pub cluster: ClusterCounters,
    /// Per-tenant scheduling counters summed across the shards.
    pub per_tenant: Vec<(usize, TenantSchedCounters)>,
    /// Requests deferred by `Busy` admission backpressure.
    pub busy_retries: u64,
}

impl ClusterSimResult {
    /// Sum of every board's partial reconfigurations.
    pub fn total_reconfigs(&self) -> u64 {
        self.boards.iter().map(|b| b.counters.reconfigs).sum()
    }

    /// Sum of every board's preemptions.
    pub fn total_preemptions(&self) -> u64 {
        self.boards.iter().map(|b| b.counters.preemptions).sum()
    }

    /// Boards that failed over during the run.
    pub fn failovers(&self) -> u64 {
        self.cluster.failovers
    }

    /// Requests migrated off failed boards (running + queued).
    pub fn migrations(&self) -> u64 {
        self.cluster.migrations
    }

    /// Virtual ns of execution destroyed by faults.
    pub fn lost_ns(&self) -> u64 {
        self.cluster.lost_ns
    }
}

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
enum ClusterEvent {
    Arrival(usize),
    /// Re-arrival of a job's remaining requests after `Busy`
    /// admission backpressure.
    Retry { job: usize, requests: usize },
    /// Completion at (board, anchor).
    Complete { board: usize, anchor: usize, job: usize },
    /// Preemption-check round (every board rounds at every event, so
    /// the tick needs no board identity — per-board dedup lives in
    /// `next_tick`).
    Tick,
    /// Injected board failure ([`FaultPlan`] outage start).
    BoardDown { board: usize },
    /// Outage end: the board rejoins the routable set (blank fabric).
    BoardRevive { board: usize },
    /// A reconfiguration-retry backoff expired: release parked work.
    RetryRelease,
}

/// Run a workload over a cluster of boards: one discrete-event heap,
/// per-board virtual clocks (each shard's core only advances at its
/// own rounds), placement at admission, work stealing before each
/// board's round, and a merged decision log.  The per-shard event
/// cadence is identical to [`simulate`]'s, so a one-board cluster
/// makes exactly the decisions of the single-board simulator — and
/// the multi-fabric daemon mirrors this loop for parity.
pub fn simulate_cluster(
    catalog: &Catalog,
    workload: &Workload,
    cfg: &ClusterSimConfig,
) -> ClusterSimResult {
    assert!(!cfg.boards.is_empty(), "a cluster needs at least one board");
    let n_boards = cfg.boards.len();
    let mut cluster = ClusterCore::new(&cfg.boards, catalog, cfg.policy, cfg.placement)
        .with_steal_threshold(cfg.steal_threshold)
        .with_checkpoint_migration(cfg.checkpoint_migration);
    // The plan is consumed (per-board attempt counters advance), so
    // each run takes its own copy — cloning the same plan into the
    // daemon replays the identical fault sequence (fault parity).
    let mut plan = cfg.faults.clone();
    let mut admit = AdmissionPipeline::new(cfg.admission);
    cluster.set_bw_partition(cfg.admission.bw_partition);
    for &(u, q) in &workload.qos {
        admit.set_qos(u, q);
        cluster.set_tenant_weight(u, q.weight);
    }

    let mut jobs_left: Vec<usize> = workload.jobs.iter().map(|j| j.requests).collect();
    let mut result = ClusterSimResult {
        makespan: 0,
        job_completion: vec![0; workload.jobs.len()],
        boards: Vec::new(),
        merged: Vec::new(),
        cluster: ClusterCounters::default(),
        per_tenant: Vec::new(),
        busy_retries: 0,
    };
    let mut busy_ns = vec![0u64; n_boards];

    let mut heap: BinaryHeap<Reverse<(SimTime, u64, ClusterEvent)>> = BinaryHeap::new();
    let mut seq = 0u64;
    for (j, job) in workload.jobs.iter().enumerate() {
        heap.push(Reverse((job.arrival, seq, ClusterEvent::Arrival(j))));
        seq += 1;
    }
    // Injected outages become ordinary virtual-time events — scheduled
    // before any dispatch, so at equal timestamps a failure is
    // processed before the completions it cancels (the daemon arms its
    // sentinels at the same point for the same ordering).
    if let Some(p) = &plan {
        for o in p.outages() {
            if o.board < n_boards {
                heap.push(Reverse((o.at_ns, seq, ClusterEvent::BoardDown { board: o.board })));
                seq += 1;
                heap.push(Reverse((
                    o.revive_at_ns(),
                    seq,
                    ClusterEvent::BoardRevive { board: o.board },
                )));
                seq += 1;
            }
        }
    }
    // Completion events cancelled by a preemption (by event seq).
    let mut cancelled: HashSet<u64> = HashSet::new();
    // (board, anchor) -> seq of the completion event running there.
    let mut running_seq: HashMap<(usize, usize), u64> = HashMap::new();
    // (board, anchor) -> (scheduled end, span) of the open dispatch,
    // so a preemption can roll back the uncompleted busy time.
    let mut open: HashMap<(usize, usize), (SimTime, usize)> = HashMap::new();
    // One pending preemption-check tick per board.
    let mut next_tick: Vec<Option<SimTime>> = vec![None; n_boards];

    while let Some(Reverse((now, s0, ev))) = heap.pop() {
        // Drain every event at this timestamp before dispatching, so
        // simultaneous arrivals/completions see each other (exactly the
        // single-board simulator's batching rule).
        let mut batch = vec![(s0, ev)];
        while let Some(Reverse((t, _, _))) = heap.peek() {
            if *t != now {
                break;
            }
            let Reverse((_, s, e)) = heap.pop().unwrap();
            batch.push((s, e));
        }
        // Ordering-fuzz hook (see the single-board loop above).
        cfg.order.permute_events(now, &mut batch);
        for (s, ev) in batch {
            match ev {
                ClusterEvent::Arrival(j) => {
                    let job = &workload.jobs[j];
                    cluster
                        .core(0)
                        .validate(&job.accel, job.pin_variant.as_deref())
                        .unwrap_or_else(|e| panic!("{e}"));
                    result.busy_retries += pipeline_enqueue(
                        &mut admit,
                        &mut heap,
                        &mut seq,
                        now,
                        j,
                        job,
                        job.requests,
                        |job, requests| ClusterEvent::Retry { job, requests },
                    );
                }
                ClusterEvent::Retry { job, requests } => {
                    let spec = &workload.jobs[job];
                    result.busy_retries += pipeline_enqueue(
                        &mut admit,
                        &mut heap,
                        &mut seq,
                        now,
                        job,
                        spec,
                        requests,
                        |job, requests| ClusterEvent::Retry { job, requests },
                    );
                }
                ClusterEvent::Tick => {} // only triggers the rounds below
                ClusterEvent::RetryRelease => {} // release happens below
                ClusterEvent::BoardDown { board } => {
                    // Cancel every in-flight completion of the failed
                    // board and roll back its uncompleted busy time —
                    // the work migrates, so it never completes here.
                    let stale: Vec<(usize, usize)> =
                        running_seq.keys().filter(|&&(b, _)| b == board).copied().collect();
                    for key in stale {
                        let vseq = running_seq.remove(&key).unwrap();
                        cancelled.insert(vseq);
                        if let Some((old_end, span)) = open.remove(&key) {
                            busy_ns[board] -= old_end.saturating_sub(now) * span as u64;
                        }
                    }
                    // Forget the board's pending preempt tick exactly
                    // like the daemon does: a post-revival round must
                    // re-arm from scratch or the tick cadences (and so
                    // the decision sequences) drift apart.
                    next_tick[board] = None;
                    cluster.mark_board_down(board, now);
                }
                ClusterEvent::BoardRevive { board } => {
                    cluster.revive_board(board);
                }
                ClusterEvent::Complete { board, anchor, job } => {
                    if cancelled.remove(&s) {
                        continue; // this dispatch was preempted mid-span
                    }
                    // Injected transient run error: the dispatch's work
                    // is lost and the request re-queued at the front of
                    // its owner's queue — it completes on a later,
                    // clean dispatch (conservation holds).
                    if plan.as_mut().is_some_and(|p| p.run_should_fail(board))
                        && cluster.fail_run(board, anchor, now)
                    {
                        if running_seq.get(&(board, anchor)) == Some(&s) {
                            running_seq.remove(&(board, anchor));
                            open.remove(&(board, anchor));
                        }
                        continue;
                    }
                    cluster.complete(board, anchor);
                    admit.complete(workload.jobs[job].user);
                    if running_seq.get(&(board, anchor)) == Some(&s) {
                        running_seq.remove(&(board, anchor));
                        open.remove(&(board, anchor));
                    }
                    jobs_left[job] -= 1;
                    if jobs_left[job] == 0 {
                        result.job_completion[job] = now;
                    }
                    result.makespan = result.makespan.max(now);
                }
            }
        }

        // Release backoff-expired retries (and revival-parked work)
        // before admitting new arrivals — oldest work first, the same
        // order the daemon uses.
        cluster.release_retries(now);

        // Batched ingest (routing happens here, at admission into the
        // cluster): the daemon dispatcher's exact rule and order.
        // With every board down, ingest waits — queued work stays in
        // the admission pipeline until a revival event re-opens it.
        if cluster.healthy_count() > 0 {
            for r in admit.ingest_ordered(&cfg.order, now) {
                cluster
                    .submit_for(r.user, r.tenant, r.job, &r.accel, r.tiles, r.pin.as_deref())
                    .unwrap_or_else(|e| panic!("{e}"));
            }
        }

        // One scheduling round per board, in board order: an idle board
        // first steals from the deepest over-threshold backlog, then
        // places as many requests as its policy allows.
        for b in 0..n_boards {
            cluster.steal_into(b);
            cluster.begin_round_at(b, now);
            while let Some(d) = cluster.next_decision(b) {
                if d.kind == DecisionKind::Preempt {
                    let vseq = running_seq
                        .remove(&(b, d.anchor))
                        .expect("preempt decision without a running dispatch");
                    cancelled.insert(vseq);
                    if let Some((old_end, span)) = open.remove(&(b, d.anchor)) {
                        busy_ns[b] -= (old_end - now) * span as u64;
                    }
                    continue;
                }
                // Injected reconfiguration fault — consumed (and the
                // per-accel streak reported) for EVERY reconfiguring
                // dispatch, success or failure, exactly as the daemon
                // does.  A failed load is rolled back and either parked
                // for a backoff retry or rejected at the cap.
                if d.reconfigure {
                    let failed = plan.as_mut().is_some_and(|p| p.reconfig_should_fail(b));
                    if let Some(disp) = cluster.reconfig_outcome(b, &d, failed, now) {
                        if let FailDisposition::Retry { at_ns } = disp {
                            heap.push(Reverse((at_ns, seq, ClusterEvent::RetryRelease)));
                            seq += 1;
                        }
                        continue; // rejections surface via take_rejected below
                    }
                }
                let busy_others = cluster.busy_anchors(b).saturating_sub(1);
                let lat = cluster.service_ns(b, &d, busy_others);
                cluster.mark_running(b, &d, now, now + lat);
                let end = now + lat;
                busy_ns[b] += lat * d.span as u64;
                open.insert((b, d.anchor), (end, d.span));
                running_seq.insert((b, d.anchor), seq);
                heap.push(Reverse((
                    end,
                    seq,
                    ClusterEvent::Complete { board: b, anchor: d.anchor, job: d.job as usize },
                )));
                seq += 1;
            }

            // Requests this shard rejected (a policy chose an unknown
            // variant): count them completed-with-failure so the run
            // terminates; built-in policies never trigger this.
            for (req, _reason) in cluster.take_rejected(b) {
                admit.complete(req.tenant);
                let j = req.job as usize;
                jobs_left[j] = jobs_left[j].saturating_sub(1);
                if jobs_left[j] == 0 {
                    result.job_completion[j] = now;
                }
            }

            // Per-board preemption-check cadence (the core-owned rule;
            // jitter moves only the heap event, never `next_tick`).
            if let Some(t) = cluster.preempt_tick_due(b, &mut next_tick[b], now) {
                heap.push(Reverse((cfg.order.jitter_tick(b, t), seq, ClusterEvent::Tick)));
                seq += 1;
            }
        }
    }

    result.boards = (0..n_boards)
        .map(|b| BoardSim {
            board: cluster.board(b),
            counters: cluster.core(b).counters().clone(),
            decisions: cluster.core(b).decision_log().copied().collect(),
            busy_ns: busy_ns[b],
        })
        .collect();
    result.merged = cluster.merged_log().copied().collect();
    result.cluster = cluster.cluster_counters().clone();
    result.per_tenant = cluster.tenant_counters().into_iter().collect();
    result
}

/// Deterministic input generation for real-compute mode.
pub fn gen_inputs(accel: &crate::accel::Accelerator, rng: &mut Rng) -> Vec<Vec<f32>> {
    accel
        .inputs
        .iter()
        .map(|spec| {
            let n = spec.elements();
            match accel.name.as_str() {
                "histogram" => (0..n).map(|_| rng.f32()).collect(),
                "black_scholes" => {
                    // (N, 5) S/K/T/r/sigma columns, all positive.
                    let rows = n / 5;
                    let mut buf = vec![0f32; n];
                    for r in 0..rows {
                        buf[r * 5] = 50.0 + 100.0 * rng.f32();
                        buf[r * 5 + 1] = 50.0 + 100.0 * rng.f32();
                        buf[r * 5 + 2] = 0.1 + 1.9 * rng.f32();
                        buf[r * 5 + 3] = 0.1 * rng.f32();
                        buf[r * 5 + 4] = 0.1 + 0.5 * rng.f32();
                    }
                    buf
                }
                _ => (0..n).map(|_| rng.normal()).collect(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::admission::QosClass;

    fn catalog() -> Catalog {
        Catalog::load_default().unwrap()
    }

    fn single_user(accel: &str, requests: usize, tiles: usize) -> Workload {
        let mut w = Workload::new();
        for j in JobSpec::frame(0, accel, 0, requests * tiles, requests) {
            w.push(j);
        }
        w
    }

    #[test]
    fn replication_speeds_up_single_user() {
        // Fig 20's core effect: more requests -> more parallelism, until
        // the region count (3 on Ultra96) is reached. Pinned to the
        // 1-region variant, as in the paper's sweep.
        let c = catalog();
        let lat = |reqs: usize| {
            let mut w = Workload::new();
            for j in JobSpec::frame_pinned(0, "mandelbrot", "mandelbrot_v1", 0, 12, reqs) {
                w.push(j);
            }
            simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic)).makespan
        };
        let l1 = lat(1);
        let l3 = lat(3);
        let l6 = lat(6);
        // "Almost linear" (paper §5.5.1): reconfiguration overhead keeps
        // it under a perfect 3x.
        assert!(
            (l1 as f64 / l3 as f64) > 2.3,
            "3 requests should be ~3x faster: {l1} vs {l3}"
        );
        // Past the region count, stagnation (Fig 21): 6 requests buy
        // little over 3.
        assert!((l3 as f64 / l6 as f64) > 0.85, "{l3} vs {l6}");
    }

    #[test]
    fn multiples_of_region_count_win() {
        // 12 tiles on 3 regions: 4 requests (uneven rounds) slower than
        // 6 requests (2 clean rounds of 3)? Paper: multiples of the
        // region count avoid leftover-bottlenecks. With equal total work
        // 3 requests beats 4 requests.
        let c = catalog();
        let w3 = {
            let mut w = Workload::new();
            for j in JobSpec::frame(0, "mandelbrot", 0, 12, 3) {
                w.push(j);
            }
            w
        };
        let w4 = {
            let mut w = Workload::new();
            for j in JobSpec::frame(0, "mandelbrot", 0, 12, 4) {
                w.push(j);
            }
            w
        };
        let cfg = SimConfig::new(ShellBoard::Ultra96, Policy::Elastic);
        let m3 = simulate(&c, &w3, &cfg).makespan;
        let m4 = simulate(&c, &w4, &cfg).makespan;
        assert!(m3 <= m4, "3 reqs {m3} should beat 4 reqs {m4} on 3 regions");
    }

    #[test]
    fn elastic_beats_fixed() {
        // Fig 15: same four single-job users, elastic vs fixed.
        let c = catalog();
        let mut w = Workload::new();
        for (u, arrival) in [(0usize, 0u64), (1, 2_000_000), (2, 4_000_000), (3, 30_000_000)] {
            for j in JobSpec::frame(u, "dct", arrival, 24, 8) {
                w.push(j);
            }
        }
        let el = simulate(&c, &w, &SimConfig::new(ShellBoard::Zcu102, Policy::Elastic));
        let fx = simulate(&c, &w, &SimConfig::new(ShellBoard::Zcu102, Policy::Fixed));
        assert!(
            el.makespan < fx.makespan,
            "elastic {} >= fixed {}",
            el.makespan,
            fx.makespan
        );
        // The elastic run must actually have replicated/reused.
        assert!(el.counters.reuses > 0);
    }

    #[test]
    fn reuse_avoids_reconfiguration() {
        let c = catalog();
        // Two users running the SAME accelerator share it in time.
        let mut w = Workload::new();
        for j in JobSpec::frame(0, "sobel", 0, 6, 6) {
            w.push(j);
        }
        for j in JobSpec::frame(1, "sobel", 0, 6, 6) {
            w.push(j);
        }
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
        // 12 requests, 3 regions: at most a handful of reconfigs, many reuses.
        assert!(r.counters.reconfigs <= 3, "reconfigs {}", r.counters.reconfigs);
        assert_eq!(r.counters.reconfigs + r.counters.reuses, 12);
    }

    #[test]
    fn dct_uses_bigger_variant_when_alone() {
        let c = catalog();
        // Long job (paper-scale): the 2-region variant's extra partial-
        // bitstream cost amortises and replacement kicks in.
        let w = single_user("dct", 2, 200);
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Zcu102, Policy::Elastic));
        assert!(
            r.trace.iter().any(|t| t.variant == "dct_v2"),
            "expected dct_v2 in trace: {:?}",
            r.trace.iter().map(|t| t.variant.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn multi_user_gets_single_region_modules() {
        let c = catalog();
        let mut w = Workload::new();
        for j in JobSpec::frame(0, "dct", 0, 8, 4) {
            w.push(j);
        }
        for j in JobSpec::frame(1, "mandelbrot", 0, 8, 4) {
            w.push(j);
        }
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
        // While both users are active, spans must be 1... the tail of the
        // run (one user drained) may still grow. Check early trace only.
        let early: Vec<_> = r.trace.iter().filter(|t| t.start == 0).collect();
        assert!(!early.is_empty());
        assert!(early.iter().all(|t| t.span == 1), "{early:?}");
        // Round-robin fairness: both users dispatched at t=0.
        let users: std::collections::HashSet<usize> = early.iter().map(|t| t.user).collect();
        assert_eq!(users.len(), 2);
    }

    #[test]
    fn trace_is_consistent() {
        let c = catalog();
        let w = single_user("fir", 6, 2);
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
        assert_eq!(r.trace.len(), 6);
        for t in &r.trace {
            assert!(t.end > t.start);
            assert!(t.region + t.span <= 3);
        }
        // No two events overlap on the same region.
        for (i, a) in r.trace.iter().enumerate() {
            for b in &r.trace[i + 1..] {
                let disjoint_regions =
                    a.region + a.span <= b.region || b.region + b.span <= a.region;
                let disjoint_time = a.end <= b.start || b.end <= a.start;
                assert!(disjoint_regions || disjoint_time, "{a:?} vs {b:?}");
            }
        }
        assert_eq!(r.makespan, r.trace.iter().map(|t| t.end).max().unwrap());
    }

    #[test]
    fn fixed_policy_isolates_users_to_one_region() {
        let c = catalog();
        let mut w = Workload::new();
        for u in 0..2 {
            for j in JobSpec::frame(u, "sobel", 0, 4, 4) {
                w.push(j);
            }
        }
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Fixed));
        let mut per_user: std::collections::HashMap<usize, std::collections::HashSet<usize>> =
            Default::default();
        for t in &r.trace {
            assert_eq!(t.span, 1);
            per_user.entry(t.user).or_default().insert(t.region);
        }
        for (u, regions) in per_user {
            assert_eq!(regions.len(), 1, "user {u} used {regions:?}");
        }
    }

    /// One tenant streaming three long pinned requests, one tenant with
    /// many short requests — the time-domain starvation scenario.
    fn streams_plus_shorts() -> Workload {
        let mut w = Workload::new();
        for _ in 0..3 {
            w.push(JobSpec::stream(0, "mandelbrot", Some("mandelbrot_v1"), 0, 120));
        }
        for j in JobSpec::frame_pinned(1, "sobel", "sobel_v1", 0, 20, 10) {
            w.push(j);
        }
        w
    }

    #[test]
    fn preemptive_policies_cut_turnaround_for_short_jobs() {
        let c = catalog();
        let w = streams_plus_shorts();
        let rtc = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
        let q = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Quantum));
        let ep = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::ElasticPreempt));
        assert_eq!(rtc.counters.preemptions, 0, "elastic is run-to-completion");
        assert!(q.counters.preemptions >= 1, "quantum must preempt: {:?}", q.counters);
        assert!(ep.counters.preemptions >= 1, "elastic-pre must preempt: {:?}", ep.counters);
        assert_eq!(
            q.counters.preemptions, q.counters.resumes,
            "every checkpointed remainder must resume"
        );
        assert_eq!(ep.counters.preemptions, ep.counters.resumes);
        let m_rtc = mean_turnaround_ns(&w, &rtc);
        let m_q = mean_turnaround_ns(&w, &q);
        let m_ep = mean_turnaround_ns(&w, &ep);
        assert!(
            m_q < m_rtc,
            "quantum turnaround {m_q:.0} must beat run-to-completion {m_rtc:.0}"
        );
        assert!(
            m_ep < m_rtc,
            "elastic-pre turnaround {m_ep:.0} must beat run-to-completion {m_rtc:.0}"
        );
    }

    #[test]
    fn preempted_trace_stays_consistent() {
        let c = catalog();
        let w = streams_plus_shorts();
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Quantum));
        assert!(r.counters.preemptions >= 1);
        // Preemption splits dispatches: at least one extra trace event.
        assert!(r.trace.len() > w.total_requests());
        for t in &r.trace {
            assert!(t.end > t.start, "{t:?}");
            assert!(t.region + t.span <= 3);
        }
        // No two allocations overlap on any region.
        for (i, a) in r.trace.iter().enumerate() {
            for b in &r.trace[i + 1..] {
                let disjoint_regions =
                    a.region + a.span <= b.region || b.region + b.span <= a.region;
                let disjoint_time = a.end <= b.start || b.end <= a.start;
                assert!(disjoint_regions || disjoint_time, "{a:?} vs {b:?}");
            }
        }
        // Tile conservation across preempt/resume splits: the trace
        // carries exactly the workload's tiles, no loss, no double-run.
        let total: usize = r.trace.iter().map(|t| t.tiles).sum();
        let expected: usize = w.jobs.iter().map(|j| j.requests * j.tiles_per_request).sum();
        assert_eq!(total, expected);
        // Every job still completes.
        assert!(r.job_completion.iter().all(|&t| t > 0));
    }

    fn hetero_boards(n: usize) -> Vec<ShellBoard> {
        (0..n)
            .map(|i| if i % 2 == 0 { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 })
            .collect()
    }

    #[test]
    fn one_board_cluster_matches_single_sim() {
        // A one-shard cluster must make exactly the single-board
        // simulator's decisions — preemptions and ticks included.
        let c = catalog();
        let w = streams_plus_shorts();
        for policy in [Policy::Elastic, Policy::Quantum] {
            let single = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, policy));
            let cl = simulate_cluster(
                &c,
                &w,
                &ClusterSimConfig::new(
                    vec![ShellBoard::Ultra96],
                    policy,
                    PlacementKind::RoundRobin,
                ),
            );
            assert_eq!(cl.boards.len(), 1);
            assert_eq!(single.decisions, cl.boards[0].decisions, "{policy:?} diverged");
            assert_eq!(single.counters, cl.boards[0].counters);
            assert_eq!(single.makespan, cl.makespan);
            assert_eq!(single.job_completion, cl.job_completion);
            // The merged log is the per-board log for one shard.
            assert!(cl.merged.iter().all(|(b, _)| *b == 0));
        }
    }

    #[test]
    fn cluster_conserves_requests_across_boards() {
        let c = catalog();
        let w = Workload::cluster_mix(6, 3, 2, 6, 300_000);
        for kind in
            [PlacementKind::RoundRobin, PlacementKind::LeastLoaded, PlacementKind::Locality]
        {
            let r = simulate_cluster(
                &c,
                &w,
                &ClusterSimConfig::new(hetero_boards(3), Policy::Elastic, kind),
            );
            // Every request routed and dispatched exactly once.
            assert_eq!(r.cluster.routed, w.total_requests() as u64, "{kind:?}");
            let placements: u64 =
                r.boards.iter().map(|b| b.counters.reconfigs + b.counters.reuses).sum();
            assert_eq!(placements, w.total_requests() as u64, "{kind:?}");
            // Every job completes, after its arrival.
            for (j, &done) in r.job_completion.iter().enumerate() {
                assert!(done >= w.jobs[j].arrival, "{kind:?} job {j}");
                assert!(done <= r.makespan);
            }
            // Per-shard logs partition the merged log.
            let merged_per_board = |b: usize| r.merged.iter().filter(|(x, _)| *x == b).count();
            for (b, board) in r.boards.iter().enumerate() {
                assert_eq!(board.decisions.len(), merged_per_board(b));
            }
        }
    }

    #[test]
    fn locality_beats_round_robin_at_four_boards() {
        // The fig23 acceptance claim: on the staggered multi-tenant mix
        // at >= 4 boards, bitstream-affinity routing pays fewer partial
        // reconfigurations AND a lower mean turnaround than blind
        // round-robin scattering.
        let c = catalog();
        let w = Workload::cluster_mix(8, 4, 3, 8, 400_000);
        let run = |kind| {
            simulate_cluster(
                &c,
                &w,
                &ClusterSimConfig::new(hetero_boards(4), Policy::Elastic, kind),
            )
        };
        let rr = run(PlacementKind::RoundRobin);
        let loc = run(PlacementKind::Locality);
        assert!(
            loc.total_reconfigs() < rr.total_reconfigs(),
            "locality {} reconfigs must beat round-robin {}",
            loc.total_reconfigs(),
            rr.total_reconfigs()
        );
        let m_rr = cluster_mean_turnaround_ns(&w, &rr);
        let m_loc = cluster_mean_turnaround_ns(&w, &loc);
        assert!(
            m_loc < m_rr,
            "locality turnaround {m_loc:.0} must beat round-robin {m_rr:.0}"
        );
    }

    #[test]
    fn heterogeneous_shards_use_their_own_fabric_models() {
        // Ultra96 shards have 3 PR regions, ZCU102 shards 4: decisions
        // on each shard must stay inside that shard's fabric.
        let c = catalog();
        let w = Workload::cluster_mix(4, 2, 3, 6, 200_000);
        let r = simulate_cluster(
            &c,
            &w,
            &ClusterSimConfig::new(hetero_boards(2), Policy::Elastic, PlacementKind::LeastLoaded),
        );
        for board in &r.boards {
            let regions = match board.board {
                ShellBoard::Zcu102 => 4,
                _ => 3,
            };
            for d in &board.decisions {
                assert!(d.anchor + d.span <= regions, "{:?}: {d:?}", board.board);
            }
        }
        // Both shards actually served work.
        assert!(r.boards.iter().all(|b| !b.decisions.is_empty()));
    }

    /// Virtual requests/second over a finished run (the shared fig24
    /// metric).
    fn throughput_rps(w: &Workload, r: &SimResult) -> f64 {
        crate::metrics::throughput_rps(w.total_requests(), r.makespan)
    }

    #[test]
    fn batched_admission_beats_per_rpc_dispatch_on_throughput() {
        // The fig24 acceptance claim, pinned as a deterministic sim
        // assertion: batched tenant-aware admission (whole backlogs
        // eligible at once) beats per-RPC blocking dispatch (one
        // request in flight per tenant, one admission per round) on
        // requests/second — the fabric parallelism a blocking client
        // can never expose.
        let c = catalog();
        for tenants in [1usize, 2] {
            // Heavy pinned work so parallelism (not reconfiguration
            // cost) dominates: the elastic core provably replicates
            // this backlog over the free regions, which a one-in-
            // flight blocking client can never trigger.
            let mut w = Workload::new();
            for u in 0..tenants {
                for j in JobSpec::frame_pinned(u, "mandelbrot", "mandelbrot_v1", 0, 48, 12) {
                    w.push(j);
                }
            }
            let batched =
                simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
            let w_rpc = w.clone().with_uniform_qos(QosClass::new(1, 1));
            let per_rpc = simulate(
                &c,
                &w_rpc,
                &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic)
                    .with_admission(AdmissionConfig::per_rpc()),
            );
            // Both dispatch every request exactly once…
            assert_eq!(batched.trace.len(), w.total_requests());
            assert_eq!(per_rpc.trace.len(), w.total_requests());
            // …but batched admission finishes strictly sooner.
            assert!(
                batched.makespan < per_rpc.makespan,
                "{tenants} tenant(s): batched {} must beat per-RPC {}",
                batched.makespan,
                per_rpc.makespan
            );
            assert!(throughput_rps(&w, &batched) > throughput_rps(&w, &per_rpc));
        }
    }

    #[test]
    fn fair_share_prevents_starvation_on_streams_plus_shorts() {
        // The no-starvation acceptance scenario: three tenants
        // streaming long pinned requests fill the whole fabric at t=0;
        // a fourth tenant brings short requests.  Under run-to-
        // completion elastic the shorts wait for a whole stream to
        // finish; under FairShare the fully starved tenant preempts
        // once a victim has run `min_run_ns` — so its first dispatch
        // lands at the 10 ms mark (the second preemption-check tick),
        // bounded and early.
        use crate::sched::PREEMPT_TICK_NS;
        let c = catalog();
        let w = Workload::tenant_mix(4, 3, 400, 10, 2);
        let fair = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::FairShare));
        let rtc = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
        assert!(fair.counters.preemptions >= 1, "fair share must preempt: {:?}", fair.counters);
        assert_eq!(fair.counters.preemptions, fair.counters.resumes);
        assert!(fair.job_completion.iter().all(|&t| t > 0), "every job completes");

        let first_dispatch = |r: &SimResult, u: usize| {
            r.trace.iter().filter(|t| t.user == u).map(|t| t.start).min().unwrap()
        };
        // Starvation is bounded: the shorts tenant is served within
        // min_run_ns + one tick of the streams filling the fabric…
        assert!(
            first_dispatch(&fair, 3) <= 3 * PREEMPT_TICK_NS,
            "fair share left tenant 3 starved until {}",
            first_dispatch(&fair, 3)
        );
        // …while run-to-completion makes it wait for a whole stream.
        assert!(
            first_dispatch(&rtc, 3) > first_dispatch(&fair, 3),
            "rtc {} vs fair {}",
            first_dispatch(&rtc, 3),
            first_dispatch(&fair, 3)
        );
        // And the fairness is productive: mean turnaround improves.
        let m_fair = mean_turnaround_ns(&w, &fair);
        let m_rtc = mean_turnaround_ns(&w, &rtc);
        assert!(
            m_fair < m_rtc,
            "fair-share turnaround {m_fair:.0} must beat run-to-completion {m_rtc:.0}"
        );
        // Per-tenant counters surface the preemption accounting.
        let preempted: u64 = fair.per_tenant.iter().map(|(_, c)| c.preempted).sum();
        assert_eq!(preempted, fair.counters.preemptions);
        let admitted: u64 = fair.per_tenant.iter().map(|(_, c)| c.admitted).sum();
        assert_eq!(admitted, w.total_requests() as u64);
        // Exactly one completed running record per request: a
        // preempted dispatch is credited only at its resumed finish.
        let completed: u64 = fair.per_tenant.iter().map(|(_, c)| c.completed).sum();
        assert_eq!(completed, w.total_requests() as u64);
    }

    #[test]
    fn busy_backpressure_retries_and_conserves_requests() {
        // A burst far above the bounded admission queue: the overflow
        // is deferred with Busy hints and retried — every request is
        // still dispatched exactly once, nothing is lost or doubled.
        let c = catalog();
        let mut w = Workload::new();
        for j in JobSpec::frame_pinned(0, "sobel", "sobel_v1", 0, 16, 16) {
            w.push(j);
        }
        let cfg = SimConfig::new(ShellBoard::Ultra96, Policy::Elastic).with_admission(
            AdmissionConfig { queue_cap: 2, ..AdmissionConfig::default() },
        );
        let r = simulate(&c, &w, &cfg);
        assert!(r.busy_retries > 0, "a 16-request burst must trip a 2-deep queue");
        assert_eq!(r.trace.len(), 16, "every deferred request is eventually dispatched");
        assert_eq!(r.counters.reconfigs + r.counters.reuses, 16);
        assert!(r.job_completion.iter().all(|&t| t > 0));
    }

    #[test]
    fn board_failover_completes_all_requests_and_beats_resubmit() {
        // The failure-domain acceptance claim: a seeded FaultPlan kills
        // 1 of 4 boards mid-run, yet 100% of admitted requests complete
        // (zero lost work) via checkpoint-based migration — and mean
        // turnaround under failover beats the drop-and-resubmit
        // baseline (the fig23-style comparison).
        let c = catalog();
        // Long pinned streams: every board carries substantial
        // in-flight progress when the outage hits.
        let mut w = Workload::new();
        for t in 0..8 {
            w.push(JobSpec::stream(t, "mandelbrot", Some("mandelbrot_v1"), 0, 60));
        }
        let base =
            ClusterSimConfig::new(hetero_boards(4), Policy::Elastic, PlacementKind::RoundRobin);
        let clean = simulate_cluster(&c, &w, &base);
        // Kill board 1 once real progress exists; no revival in-run.
        let outage = FaultPlan::new(3).with_outage(1, clean.makespan / 2, clean.makespan * 4);
        let mk = |resubmit: bool| {
            let mut cfg = ClusterSimConfig::new(
                hetero_boards(4),
                Policy::Elastic,
                PlacementKind::RoundRobin,
            )
            .with_faults(outage.clone());
            if resubmit {
                cfg = cfg.with_drop_and_resubmit();
            }
            simulate_cluster(&c, &w, &cfg)
        };
        let failover = mk(false);
        assert_eq!(failover.failovers(), 1);
        assert!(failover.migrations() >= 1, "{:?}", failover.cluster);
        assert!(failover.job_completion.iter().all(|&t| t > 0), "every job completes");
        let completed: u64 = failover.per_tenant.iter().map(|(_, tc)| tc.completed).sum();
        assert_eq!(completed, w.total_requests() as u64, "zero lost work");
        let rejected: u64 = failover.per_tenant.iter().map(|(_, tc)| tc.rejected).sum();
        assert_eq!(rejected, 0, "outages alone must never reject");
        assert!(failover.makespan >= clean.makespan, "failure is never free");
        // Checkpointed migration preserves progress that the
        // drop-and-resubmit baseline throws away.
        let resub = mk(true);
        assert!(resub.job_completion.iter().all(|&t| t > 0));
        let m_ck = cluster_mean_turnaround_ns(&w, &failover);
        let m_rs = cluster_mean_turnaround_ns(&w, &resub);
        assert!(
            m_ck < m_rs,
            "checkpoint failover {m_ck:.0} must beat drop-and-resubmit {m_rs:.0}"
        );
        assert!(
            failover.lost_ns() < resub.lost_ns(),
            "{} vs {}",
            failover.lost_ns(),
            resub.lost_ns()
        );
    }

    #[test]
    fn injected_faults_conserve_requests() {
        // Reconfiguration and transient-run faults at aggressive rates:
        // every admitted request still either completes or surfaces as
        // a structured rejection at the retry cap — exactly once.
        let c = catalog();
        let w = Workload::cluster_mix(6, 3, 2, 6, 300_000);
        let plan = FaultPlan::new(11).with_reconfig_rate(0.3).with_run_rate(0.2);
        let cfg =
            ClusterSimConfig::new(hetero_boards(3), Policy::Elastic, PlacementKind::Locality)
                .with_faults(plan);
        let r = simulate_cluster(&c, &w, &cfg);
        assert!(
            r.cluster.reconfig_failures > 0 && r.cluster.run_faults > 0,
            "faults must actually fire: {:?}",
            r.cluster
        );
        let admitted: u64 = r.per_tenant.iter().map(|(_, tc)| tc.admitted).sum();
        let completed: u64 = r.per_tenant.iter().map(|(_, tc)| tc.completed).sum();
        let rejected: u64 = r.per_tenant.iter().map(|(_, tc)| tc.rejected).sum();
        assert_eq!(admitted, w.total_requests() as u64);
        assert_eq!(completed + rejected, admitted, "conserved under faults");
        assert!(r.job_completion.iter().all(|&t| t > 0), "every job terminates");
        assert!(r.cluster.lost_ns > 0);
        assert_eq!(
            r.cluster.reconfig_failures,
            r.cluster.reconfig_retries + r.cluster.reconfig_rejections,
            "every failure is either retried or rejected: {:?}",
            r.cluster
        );
    }

    #[test]
    fn decision_log_matches_trace() {
        let c = catalog();
        let w = single_user("fir", 4, 2);
        let r = simulate(&c, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
        let symbols = crate::sched::SymbolTable::from_catalog(&c);
        assert_eq!(r.decisions.len(), r.trace.len());
        for (d, t) in r.decisions.iter().zip(&r.trace) {
            assert_eq!(d.anchor, t.region);
            assert_eq!(d.span, t.span);
            assert_eq!(symbols.resolve(d.variant), t.variant);
            assert_eq!(d.reconfigure, t.reconfigured);
        }
        assert_eq!(r.counters.reconfigs + r.counters.reuses, r.trace.len() as u64);
    }
}
