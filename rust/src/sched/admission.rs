//! # The tenant-aware admission pipeline (shared by daemon and sim)
//!
//! FOS's multi-tenant daemon (§4.4, mode 3) arbitrates the FPGA
//! transparently across tenants, but arbitration starts *before* the
//! scheduler: something has to decide which queued client work is
//! eligible to enter a scheduling round at all, and a single greedy
//! client must not be able to monopolise that entry point (the failure
//! mode THEMIS-style fair FPGA schedulers are built against).  This
//! module is that stage: a pure, clock-free state machine
//! ([`AdmissionPipeline`]) sitting between client submission and
//! [`super::SchedCore::submit`], driven by *both* harnesses — the live
//! daemon dispatcher and the discrete-event simulator — so the batched
//! ingest order is bit-identical on both paths (the same two-harness
//! discipline as the scheduler core; parity tests depend on it).
//!
//! Three mechanisms, all per tenant:
//!
//! - **Bounded queues (backpressure).**  Each tenant owns one FIFO of
//!   not-yet-admitted requests, capped at
//!   [`AdmissionConfig::queue_cap`].  An overflowing [`enqueue`]
//!   returns a structured [`AdmitError::Busy`] carrying a retry hint
//!   instead of stalling the caller — the daemon turns it into a
//!   `busy` error reply, the simulator into a delayed re-arrival.
//!
//! - **Weighted deficit round-robin (ingest order).**  One [`ingest`]
//!   call admits eligible queued work for one scheduling round.
//!   Tenants are visited in id order; each backlogged, quota-eligible
//!   tenant earns `weight x quantum_tiles` of deficit credit per pass
//!   and admits head requests while the credit covers their tiles —
//!   the classic DRR guarantee that a tenant's admitted-tile share
//!   converges to its weight share, with per-tenant deviation bounded
//!   by one quantum plus one maximal request.  Passes repeat until the
//!   round's [`AdmissionConfig::batch_cap`] is spent or nothing more
//!   is eligible, so a deficit too small for a large head request can
//!   never wedge the pipeline.
//!
//! - **Token-bucket in-flight quotas.**  Each tenant holds
//!   [`QosClass::max_inflight`] tokens; admission takes one,
//!   [`complete`] returns it.  A tenant at its quota stops earning
//!   deficit (no unbounded credit hoarding) and stops admitting until
//!   work drains — the cap that keeps one tenant from flooding the
//!   scheduler queues far beyond its share.
//!
//! The default configuration is deliberately permissive (large queue
//! cap; unbounded quantum, batch and in-flight quotas): ingest then
//! drains every queue in tenant order, which preserves the pre-pipeline decision
//! sequences the sim/daemon parity suite pins down.  QoS only bites
//! when a harness configures it — the daemon's `session` RPC, the
//! simulator's [`super::Workload`] QoS map, or a bench sweeping the
//! fig24 admission comparison.
//!
//! [`enqueue`]: AdmissionPipeline::enqueue
//! [`ingest`]: AdmissionPipeline::ingest
//! [`complete`]: AdmissionPipeline::complete

use std::collections::{BTreeMap, VecDeque};

/// Per-tenant queue bound of the default configuration — deep enough
/// that no existing workload/test ever trips it, bounded so a runaway
/// client cannot grow daemon memory without seeing `Busy`.
pub const DEFAULT_ADMIT_QUEUE_CAP: usize = 1024;

/// Default DRR quantum (tiles of credit per weight unit per pass).
/// Effectively unbounded: the saturating deficit then covers any
/// request immediately, so the default ingest drains queues in strict
/// tenant-id + FIFO order — exactly the pre-pipeline admission order
/// the sim/daemon parity suite pins down.  Configure a finite quantum
/// (CLI `--quantum-tiles`, [`AdmissionConfig`]) to arm weighted DRR.
pub const DEFAULT_QUANTUM_TILES: u64 = u64::MAX;

/// A tenant's quality-of-service class: DRR weight plus in-flight
/// quota.  Set over the wire (`session` RPC), per workload
/// ([`super::Workload::set_qos`]), or defaulted to `{1, unbounded}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QosClass {
    /// Relative DRR weight (credit per pass = `weight x quantum`).
    pub weight: u32,
    /// Token-bucket capacity: admitted-but-uncompleted requests this
    /// tenant may have in the scheduler at once.
    pub max_inflight: usize,
}

impl Default for QosClass {
    fn default() -> QosClass {
        QosClass { weight: 1, max_inflight: usize::MAX }
    }
}

impl QosClass {
    pub fn new(weight: u32, max_inflight: usize) -> QosClass {
        QosClass { weight: weight.max(1), max_inflight: max_inflight.max(1) }
    }
}

/// Pipeline tuning shared by the daemon and the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionConfig {
    /// Bounded-queue backpressure: queued (not yet admitted) requests
    /// a tenant may hold before `enqueue` answers [`AdmitError::Busy`].
    pub queue_cap: usize,
    /// DRR quantum: tiles of deficit credit per weight unit per pass.
    pub quantum_tiles: u64,
    /// Requests one [`AdmissionPipeline::ingest`] round may admit in
    /// total (across all tenants).  `usize::MAX` = drain everything
    /// eligible (the batched default); `1` models a per-RPC trickle
    /// (the fig24 baseline).
    pub batch_cap: usize,
    /// Weighted memory-bandwidth partitioning (the tenant-isolation
    /// QoS knob): when `true` the scheduler core charges each
    /// dispatch's DMA at its tenant's share of the contended bandwidth
    /// — share ∝ the same [`QosClass::weight`] DRR uses, work-
    /// conserving when other tenants are idle
    /// ([`crate::memsim::DdrModel::transfer_ns_partitioned`]).  Off by
    /// default: service times then match the historical equal-split
    /// model exactly.
    pub bw_partition: bool,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            queue_cap: DEFAULT_ADMIT_QUEUE_CAP,
            quantum_tiles: DEFAULT_QUANTUM_TILES,
            batch_cap: usize::MAX,
            bw_partition: false,
        }
    }
}

impl AdmissionConfig {
    /// The per-RPC dispatch baseline fig24 compares against: one
    /// request admitted per ingest round.  Pair with per-tenant
    /// `max_inflight = 1` to model a strictly blocking submit→wait
    /// client.
    pub fn per_rpc() -> AdmissionConfig {
        AdmissionConfig { batch_cap: 1, ..AdmissionConfig::default() }
    }

    /// Turn on weighted memory-bandwidth partitioning.
    pub fn with_bw_partition(mut self) -> AdmissionConfig {
        self.bw_partition = true;
        self
    }
}

/// One client request waiting for (or clearing) admission.  Mirrors
/// the fields [`super::SchedCore::submit_for`] takes; `job` is the
/// harness token echoed back in decisions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdmitRequest {
    /// Scheduler slot (round-robin identity inside the core).
    pub user: usize,
    /// QoS identity (several users may share one tenant).
    pub tenant: usize,
    pub job: u64,
    pub accel: String,
    pub tiles: usize,
    pub pin: Option<String>,
}

/// Why an [`AdmissionPipeline::enqueue`] was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmitError {
    /// Bounded-queue backpressure: the tenant's admission queue is
    /// full.  `retry_after_ns` is a deterministic backoff hint scaled
    /// by the queue depth — clients retry instead of stalling a
    /// connection thread, the simulator re-schedules the arrival.
    Busy { tenant: usize, queued: usize, retry_after_ns: u64 },
}

impl AdmitError {
    pub fn retry_after_ns(&self) -> u64 {
        match self {
            AdmitError::Busy { retry_after_ns, .. } => *retry_after_ns,
        }
    }
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::Busy { tenant, queued, retry_after_ns } => write!(
                f,
                "busy: tenant {tenant} admission queue full ({queued} queued); retry in ~{} ms",
                retry_after_ns / 1_000_000
            ),
        }
    }
}

/// Per-tenant admission accounting (the pipeline half of the
/// per-tenant observability surface; the scheduler half lives in
/// [`super::SchedCore`]'s tenant counters).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantAdmitCounters {
    /// Requests accepted into the admission queue.
    pub enqueued: u64,
    /// Requests handed to the scheduler by `ingest`.
    pub admitted: u64,
    /// Tiles those admitted requests carried (the DRR share metric).
    pub admitted_tiles: u64,
    /// Admitted requests whose completion returned the in-flight token.
    pub completed: u64,
    /// `enqueue` calls refused with [`AdmitError::Busy`].
    pub rejected: u64,
}

#[derive(Debug, Default)]
struct TenantState {
    queue: VecDeque<AdmitRequest>,
    qos: QosClass,
    /// DRR deficit credit (tiles).
    deficit: u64,
    /// Admitted-but-uncompleted requests (consumed tokens).
    inflight: usize,
    counters: TenantAdmitCounters,
    /// Tenant departed: remove the state once fully drained.
    retired: bool,
}

/// The tenant-aware admission stage: bounded per-tenant queues feeding
/// weighted-DRR batched ingest under token-bucket in-flight quotas.
/// Pure and clock-free — the harness owns time; `retry_after_ns` hints
/// are derived from queue depth only, so both harnesses compute
/// identical values.
pub struct AdmissionPipeline {
    cfg: AdmissionConfig,
    tenants: BTreeMap<usize, TenantState>,
    /// Circular DRR scan position: the tenant id the next ingest round
    /// resumes at after a `batch_cap` cut, so a finite budget can
    /// never starve high-id tenants (with the unbounded default this
    /// stays 0 and ingest always runs in tenant-id order).
    cursor: usize,
}

impl AdmissionPipeline {
    pub fn new(cfg: AdmissionConfig) -> AdmissionPipeline {
        AdmissionPipeline { tenants: BTreeMap::new(), cfg, cursor: 0 }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    fn state(&mut self, tenant: usize) -> &mut TenantState {
        self.tenants.entry(tenant).or_default()
    }

    /// Set (or update) a tenant's QoS class.  Also un-retires the
    /// tenant — a rebinding session reuses the drained state.
    pub fn set_qos(&mut self, tenant: usize, qos: QosClass) {
        let t = self.state(tenant);
        t.qos = qos;
        t.retired = false;
    }

    pub fn qos(&self, tenant: usize) -> QosClass {
        self.tenants.get(&tenant).map(|t| t.qos).unwrap_or_default()
    }

    /// Queue room left before `enqueue` answers `Busy` — the daemon
    /// pre-checks a whole batch against this so a batch is accepted or
    /// refused atomically (request conservation stays trivial).
    pub fn free_capacity(&self, tenant: usize) -> usize {
        let queued = self.tenants.get(&tenant).map(|t| t.queue.len()).unwrap_or(0);
        self.cfg.queue_cap.saturating_sub(queued)
    }

    /// Deterministic backoff hint for a full tenant queue.
    fn busy(&self, tenant: usize) -> AdmitError {
        let queued = self.tenants.get(&tenant).map(|t| t.queue.len()).unwrap_or(0);
        AdmitError::Busy {
            tenant,
            queued,
            retry_after_ns: 1_000_000 * (queued as u64 + 1),
        }
    }

    /// Record `n` requests refused with `Busy` *without* an `enqueue`
    /// attempt — the daemon pre-checks whole batches against
    /// [`AdmissionPipeline::free_capacity`] and refuses them atomically,
    /// so the per-tenant rejection accounting must be credited
    /// explicitly on that path.
    pub fn note_rejected(&mut self, tenant: usize, n: u64) {
        self.state(tenant).counters.rejected += n;
    }

    /// Accept one request into its tenant's admission queue, or refuse
    /// with [`AdmitError::Busy`] when the bounded queue is full.
    pub fn enqueue(&mut self, req: AdmitRequest) -> Result<(), AdmitError> {
        if self.free_capacity(req.tenant) == 0 {
            let err = self.busy(req.tenant);
            self.state(req.tenant).counters.rejected += 1;
            return Err(err);
        }
        self.enqueue_forced(req);
        Ok(())
    }

    /// [`AdmissionPipeline::enqueue`] without the bounded-queue check —
    /// for callers that enforce (or deliberately exempt) capacity at a
    /// coarser granularity: the daemon pre-checks async batches
    /// atomically against [`AdmissionPipeline::free_capacity`], and
    /// exempts blocking `run` batches entirely (a connection holds at
    /// most one, so the connection cap already bounds that state).
    pub fn enqueue_forced(&mut self, req: AdmitRequest) {
        let t = self.state(req.tenant);
        t.retired = false;
        t.counters.enqueued += 1;
        t.queue.push_back(req);
    }

    /// One batched ingest round: weighted deficit round-robin over the
    /// tenants, bounded by each tenant's in-flight quota and the
    /// round's `batch_cap`.  Returns the admitted requests in the
    /// exact order the scheduler must see them — both harnesses feed
    /// this straight into `SchedCore::submit_for`, which is what keeps
    /// their decision sequences identical.
    pub fn ingest(&mut self) -> Vec<AdmitRequest> {
        // Degenerate configs must not wedge the credit loop: a zero
        // quantum or zero weight would earn nothing forever.
        let quantum = self.cfg.quantum_tiles.max(1);
        let mut budget = self.cfg.batch_cap;
        let mut out = Vec::new();
        let ids: Vec<usize> = self.tenants.keys().copied().collect();
        if ids.is_empty() {
            return out;
        }
        // Resume the circular scan at the tenant AFTER the previous
        // round's budget cut: over consecutive rounds every tenant
        // leads a round equally often, so a finite batch_cap cannot
        // let one heavy tenant monopolise the budget round after
        // round.
        let start = ids.iter().position(|&id| id >= self.cursor).unwrap_or(0);
        'passes: loop {
            let mut admitted_this_pass = false;
            let mut deficit_starved = false;
            for k in 0..ids.len() {
                let id = ids[(start + k) % ids.len()];
                if budget == 0 {
                    self.cursor = id;
                    break 'passes;
                }
                let t = self.tenants.get_mut(&id).expect("tenant ids snapshot");
                if t.queue.is_empty() || t.inflight >= t.qos.max_inflight {
                    continue;
                }
                // Credit this pass's quantum (saturating: an unbounded
                // quantum pins the deficit at MAX = admit everything).
                // Banked credit is capped at a couple of quanta — or
                // the head request's size, whichever is larger, so an
                // oversized head can still save up for itself — which
                // keeps a budget-cut tenant from hoarding unbounded
                // credit it could never have spent.
                let earn = quantum.saturating_mul(t.qos.weight.max(1) as u64);
                let bank_cap = earn
                    .saturating_mul(2)
                    .max(t.queue.front().map(|h| h.tiles as u64).unwrap_or(0));
                if t.deficit < bank_cap {
                    t.deficit = t.deficit.saturating_add(earn);
                }
                while budget > 0 && t.inflight < t.qos.max_inflight {
                    let Some(head) = t.queue.front() else { break };
                    let cost = head.tiles as u64;
                    if cost > t.deficit {
                        deficit_starved = true;
                        break;
                    }
                    let req = t.queue.pop_front().unwrap();
                    t.deficit -= cost;
                    t.inflight += 1;
                    t.counters.admitted += 1;
                    t.counters.admitted_tiles += cost;
                    budget -= 1;
                    out.push(req);
                    admitted_this_pass = true;
                }
                if budget == 0 {
                    // Budget exhausted: the next round starts at the
                    // NEXT tenant, whoever was being served (their
                    // banked deficit survives for their next turn).
                    self.cursor = ids[(start + k + 1) % ids.len()];
                    break 'passes;
                }
                if t.queue.is_empty() {
                    // Classic DRR: an emptied queue forfeits its credit
                    // so idleness never banks future share.
                    t.deficit = 0;
                }
            }
            // Keep passing while credit growth can still admit more:
            // stopping on a deficit-starved pass would wedge a pipeline
            // whose only queued work is larger than one quantum.
            if !admitted_this_pass && !deficit_starved {
                break;
            }
        }
        // No retirement sweep needed: admitting raises `inflight`, so
        // ingest can never leave a retired tenant fully drained.
        out
    }

    /// [`AdmissionPipeline::ingest`] with the ordering-fuzz hook
    /// applied: the batch boundary stays put (the same requests are
    /// admitted this round) but their submission order within the
    /// round becomes a seeded permutation keyed by `now`.  Identity
    /// strategies return the DRR order untouched, and any seeded order
    /// is one both harnesses compute identically at the same virtual
    /// time — see [`super::OrderStrategy`].
    pub fn ingest_ordered(
        &mut self,
        order: &super::scenario::OrderStrategy,
        now: u64,
    ) -> Vec<AdmitRequest> {
        let mut batch = self.ingest();
        order.permute_ingest(now, &mut batch);
        batch
    }

    /// An admitted request finished (completed, failed, rejected
    /// downstream, or was dropped with its user): return the tenant's
    /// in-flight token.  Only this tenant can have become sweepable,
    /// so retirement is checked in O(1), not with a full-map sweep.
    pub fn complete(&mut self, tenant: usize) {
        if let Some(t) = self.tenants.get_mut(&tenant) {
            t.inflight = t.inflight.saturating_sub(1);
            t.counters.completed += 1;
            if t.retired && t.queue.is_empty() && t.inflight == 0 {
                self.tenants.remove(&tenant);
            }
        }
    }

    /// Drop every queued (not yet admitted) request of scheduler slot
    /// `user` — the departed-connection path.  Admitted requests are
    /// the scheduler's to fail; their tokens come back via
    /// [`AdmissionPipeline::complete`].
    pub fn drop_user(&mut self, user: usize) -> Vec<AdmitRequest> {
        let mut out = Vec::new();
        for t in self.tenants.values_mut() {
            let mut kept = VecDeque::with_capacity(t.queue.len());
            while let Some(r) = t.queue.pop_front() {
                if r.user == user {
                    out.push(r);
                } else {
                    kept.push_back(r);
                }
            }
            t.queue = kept;
        }
        // Dropping queued work may fully drain a retired tenant.
        self.sweep_retired();
        out
    }

    /// Mark a tenant departed: its state is removed as soon as the
    /// queue and in-flight count drain (immediately, if already idle).
    /// Keeps a long-lived daemon's pipeline bounded by *live* tenants.
    pub fn retire(&mut self, tenant: usize) {
        if let Some(t) = self.tenants.get_mut(&tenant) {
            t.retired = true;
            if t.queue.is_empty() && t.inflight == 0 {
                self.tenants.remove(&tenant);
            }
        }
    }

    fn sweep_retired(&mut self) {
        self.tenants
            .retain(|_, t| !(t.retired && t.queue.is_empty() && t.inflight == 0));
    }

    /// Requests queued across every tenant (not yet admitted).
    pub fn queued(&self) -> usize {
        self.tenants.values().map(|t| t.queue.len()).sum()
    }

    pub fn queued_of(&self, tenant: usize) -> usize {
        self.tenants.get(&tenant).map(|t| t.queue.len()).unwrap_or(0)
    }

    pub fn inflight_of(&self, tenant: usize) -> usize {
        self.tenants.get(&tenant).map(|t| t.inflight).unwrap_or(0)
    }

    /// `true` when an ingest round could admit something right now —
    /// the signal harnesses use to decide whether a scheduling round
    /// is due.  (Deficit shortfalls don't count: `ingest` loops its
    /// credit passes, so only in-flight quotas can make queued work
    /// ineligible.)
    pub fn has_eligible(&self) -> bool {
        self.tenants
            .values()
            .any(|t| !t.queue.is_empty() && t.inflight < t.qos.max_inflight)
    }

    /// Per-tenant admission counters, tenant id ascending.
    pub fn tenant_counters(&self) -> Vec<(usize, TenantAdmitCounters)> {
        self.tenants.iter().map(|(&id, t)| (id, t.counters)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(user: usize, tenant: usize, job: u64, tiles: usize) -> AdmitRequest {
        AdmitRequest {
            user,
            tenant,
            job,
            accel: "vadd".to_string(),
            tiles,
            pin: None,
        }
    }

    #[test]
    fn default_config_drains_in_tenant_order() {
        let mut p = AdmissionPipeline::new(AdmissionConfig::default());
        p.enqueue(req(1, 1, 10, 4)).unwrap();
        p.enqueue(req(0, 0, 0, 400)).unwrap();
        p.enqueue(req(0, 0, 1, 400)).unwrap();
        let order: Vec<u64> = p.ingest().into_iter().map(|r| r.job).collect();
        // Tenant 0 first (id order), fully drained despite the huge
        // requests — the permissive default never reorders admission
        // away from tenant-id-then-FIFO.
        assert_eq!(order, vec![0, 1, 10]);
        assert_eq!(p.queued(), 0);
        assert!(!p.has_eligible());
    }

    #[test]
    fn bounded_queue_rejects_with_busy() {
        let cfg = AdmissionConfig { queue_cap: 2, ..AdmissionConfig::default() };
        let mut p = AdmissionPipeline::new(cfg);
        p.enqueue(req(0, 0, 0, 1)).unwrap();
        p.enqueue(req(0, 0, 1, 1)).unwrap();
        let err = p.enqueue(req(0, 0, 2, 1)).unwrap_err();
        match err {
            AdmitError::Busy { tenant, queued, retry_after_ns } => {
                assert_eq!((tenant, queued), (0, 2));
                assert!(retry_after_ns > 0);
            }
        }
        // Another tenant is unaffected by the full queue.
        p.enqueue(req(1, 1, 3, 1)).unwrap();
        let c = p.tenant_counters();
        assert_eq!(c[0].1.rejected, 1);
        assert_eq!(c[0].1.enqueued, 2);
        // Draining frees capacity again.
        assert_eq!(p.ingest().len(), 3);
        assert!(p.enqueue(req(0, 0, 4, 1)).is_ok());
    }

    #[test]
    fn inflight_quota_gates_admission_until_completion() {
        let mut p = AdmissionPipeline::new(AdmissionConfig::default());
        p.set_qos(0, QosClass::new(1, 2));
        for j in 0..5 {
            p.enqueue(req(0, 0, j, 1)).unwrap();
        }
        assert_eq!(p.ingest().len(), 2, "token bucket caps the first round");
        assert_eq!(p.inflight_of(0), 2);
        assert!(!p.has_eligible(), "at quota: nothing eligible");
        assert_eq!(p.ingest().len(), 0);
        p.complete(0);
        assert!(p.has_eligible());
        assert_eq!(p.ingest().len(), 1, "one token back, one admission");
        p.complete(0);
        p.complete(0);
        assert_eq!(p.ingest().len(), 2);
        assert_eq!(p.queued(), 0);
        let c = p.tenant_counters()[0].1;
        assert_eq!(c.admitted, 5);
        assert_eq!(c.completed, 3);
    }

    #[test]
    fn weighted_drr_shares_a_bounded_batch() {
        // Two fully backlogged tenants, weight 3 vs 1, small-but-equal
        // requests, a finite per-round budget: admitted tiles must
        // track the 3:1 weight ratio (within one quantum + request).
        let cfg = AdmissionConfig {
            queue_cap: usize::MAX,
            quantum_tiles: 4,
            batch_cap: 8,
            ..AdmissionConfig::default()
        };
        let mut p = AdmissionPipeline::new(cfg);
        p.set_qos(0, QosClass::new(3, usize::MAX));
        p.set_qos(1, QosClass::new(1, usize::MAX));
        let mut job = 0;
        for t in 0..2usize {
            for _ in 0..400 {
                p.enqueue(req(t, t, job, 2)).unwrap();
                job += 1;
            }
        }
        for _ in 0..40 {
            let batch = p.ingest();
            assert!(batch.len() <= 8, "batch cap violated: {}", batch.len());
        }
        let c = p.tenant_counters();
        let (a, b) = (c[0].1.admitted_tiles as f64, c[1].1.admitted_tiles as f64);
        assert!(a > 0.0 && b > 0.0, "both tenants must progress: {a} vs {b}");
        let ratio = a / b;
        assert!(
            (2.2..=3.8).contains(&ratio),
            "weighted share drifted from 3:1: {a} vs {b} (ratio {ratio:.2})"
        );
        // Neither queue drained (the premise of the share claim).
        assert!(p.queued_of(0) > 0 && p.queued_of(1) > 0);
    }

    #[test]
    fn oversized_request_eventually_admits() {
        // A head request larger than one quantum accumulates deficit
        // across passes inside a single ingest call — the pipeline can
        // never wedge on it.
        let cfg = AdmissionConfig {
            quantum_tiles: 4,
            ..AdmissionConfig::default()
        };
        let mut p = AdmissionPipeline::new(cfg);
        p.enqueue(req(0, 0, 0, 1000)).unwrap();
        let got = p.ingest();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].tiles, 1000);
    }

    #[test]
    fn drop_user_and_retire_clean_up() {
        let mut p = AdmissionPipeline::new(AdmissionConfig::default());
        p.set_qos(7, QosClass::new(2, 4));
        p.enqueue(req(1, 7, 0, 1)).unwrap();
        p.enqueue(req(2, 7, 1, 1)).unwrap();
        p.enqueue(req(1, 7, 2, 1)).unwrap();
        let dropped = p.drop_user(1);
        assert_eq!(dropped.iter().map(|r| r.job).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(p.queued_of(7), 1);
        // Retire with work still queued/in flight: state survives
        // until drained, then disappears.
        assert_eq!(p.ingest().len(), 1);
        p.retire(7);
        assert_eq!(p.inflight_of(7), 1, "retired tenant still drains");
        p.complete(7);
        assert!(p.tenant_counters().is_empty(), "drained retired tenant removed");
    }
}
