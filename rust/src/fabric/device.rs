//! Device catalog: the two Zynq UltraScale+ parts behind the paper's
//! three boards.
//!
//! Column layouts are synthesised to reproduce the paper's Table 1
//! PR-region resources exactly:
//! - ZU3EG (Ultra96, UltraZed): PR window of 37 CLB + 6 BRAM + 5 DSP
//!   columns × 60 rows = 17760 LUTs / 35520 FFs / 72 BRAM36 / 120 DSP48
//!   per region — the paper's numbers to the digit.
//! - ZU9EG (ZCU102): PR window of 68 CLB + 9 BRAM + 14 DSP columns × 60
//!   rows = 32640 LUTs / 65280 FFs / 108 BRAM36 / 336 DSP48 per region.
//!
//! Whole-chip totals land within ~1% of the real silicon (see the
//! Table 1 bench for paper-vs-measured chip utilisation).

use super::{ColumnKind, Resources, CLOCK_REGION_ROWS};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// XCZU3EG — Ultra96 and UltraZed boards.
    Zu3eg,
    /// XCZU9EG — ZCU102 development kit.
    Zu9eg,
}

impl DeviceKind {
    pub fn name(self) -> &'static str {
        match self {
            DeviceKind::Zu3eg => "xczu3eg",
            DeviceKind::Zu9eg => "xczu9eg",
        }
    }
}

/// A modelled FPGA: a column sequence replicated over `rows` tile rows,
/// split into clock regions of 60 rows.
#[derive(Debug, Clone)]
pub struct Device {
    pub kind: DeviceKind,
    pub columns: Vec<ColumnKind>,
    pub rows: usize,
}

impl Device {
    pub fn new(kind: DeviceKind) -> Device {
        match kind {
            // 3 clock regions. PR window = columns 0..48, static = 48..62
            // (12 CLB columns for the shell + 2 PS columns).
            DeviceKind::Zu3eg => Device {
                kind,
                columns: interleave(37, 6, 5)
                    .into_iter()
                    .chain(std::iter::repeat(ColumnKind::Clb).take(12))
                    .chain(std::iter::repeat(ColumnKind::Ps).take(2))
                    .collect(),
                rows: 3 * CLOCK_REGION_ROWS,
            },
            // 7 clock regions. The ZCU102's irregular layout (paper §5.1.1)
            // is modelled by reserving columns 0..8 (PS + config column)
            // and everything right of the PR window for the static shell;
            // only clock regions 1..5 host relocatable slots.
            DeviceKind::Zu9eg => Device {
                kind,
                columns: std::iter::repeat(ColumnKind::Ps)
                    .take(4)
                    .chain(std::iter::repeat(ColumnKind::Clb).take(4))
                    .chain(interleave(68, 9, 14))
                    .chain(std::iter::repeat(ColumnKind::Clb).take(10))
                    .chain(interleave(0, 2, 1))
                    .collect(),
                rows: 7 * CLOCK_REGION_ROWS,
            },
        }
    }

    pub fn clock_regions(&self) -> usize {
        self.rows / CLOCK_REGION_ROWS
    }

    /// The column window PR regions live in (start inclusive, end
    /// exclusive) and the clock regions hosting relocatable slots.
    pub fn pr_window(&self) -> (usize, usize, std::ops::Range<usize>) {
        match self.kind {
            DeviceKind::Zu3eg => (0, 48, 0..3),
            DeviceKind::Zu9eg => (8, 99, 1..5),
        }
    }

    /// Resources of one column over `rows` rows: 1 CLB (8 LUT / 16 FF)
    /// per row, 1 BRAM36 per 5 rows, 24 DSP48 per 60-row clock region.
    pub fn column_resources(&self, kind: ColumnKind, rows: usize) -> Resources {
        match kind {
            ColumnKind::Clb => Resources {
                luts: 8 * rows,
                ffs: 16 * rows,
                brams: 0,
                dsps: 0,
            },
            ColumnKind::Bram => Resources {
                luts: 0,
                ffs: 0,
                brams: rows / 5,
                dsps: 0,
            },
            ColumnKind::Dsp => Resources {
                luts: 0,
                ffs: 0,
                brams: 0,
                dsps: rows * 24 / CLOCK_REGION_ROWS,
            },
            ColumnKind::Ps => Resources::ZERO,
        }
    }

    /// Total resources of a rectangular tile window.
    pub fn window_resources(&self, col_start: usize, col_end: usize, rows: usize) -> Resources {
        let mut total = Resources::ZERO;
        for &kind in &self.columns[col_start..col_end] {
            total.add(self.column_resources(kind, rows));
        }
        total
    }

    /// Whole-chip totals (Table 1 denominators).
    pub fn chip_resources(&self) -> Resources {
        self.window_resources(0, self.columns.len(), self.rows)
    }
}

/// Evenly interleave BRAM and DSP columns among CLB columns, the way real
/// UltraScale+ fabric scatters hard-block columns through the logic.
fn interleave(clb: usize, bram: usize, dsp: usize) -> Vec<ColumnKind> {
    let total = clb + bram + dsp;
    let mut cols = vec![ColumnKind::Clb; total];
    place_evenly(&mut cols, bram, 0.5, ColumnKind::Bram);
    place_evenly(&mut cols, dsp, 0.25, ColumnKind::Dsp);
    debug_assert_eq!(cols.iter().filter(|&&c| c == ColumnKind::Bram).count(), bram);
    debug_assert_eq!(cols.iter().filter(|&&c| c == ColumnKind::Dsp).count(), dsp);
    cols
}

/// Drop `count` columns of `kind` at evenly-spaced slots, displacing CLB
/// columns; `offset` staggers BRAM vs DSP so they don't collide.
fn place_evenly(slots: &mut [ColumnKind], count: usize, offset: f64, kind: ColumnKind) {
    let total = slots.len();
    for k in 0..count {
        let mut idx =
            (((k as f64 + offset) / count as f64) * total as f64) as usize;
        idx = idx.min(total - 1);
        // Collision with an earlier hard column: take the next free slot.
        while idx < total && slots[idx] != ColumnKind::Clb {
            idx += 1;
        }
        if idx >= total {
            idx = slots
                .iter()
                .rposition(|&c| c == ColumnKind::Clb)
                .expect("more hard columns than slots");
        }
        slots[idx] = kind;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zu3eg_pr_window_matches_table1() {
        let d = Device::new(DeviceKind::Zu3eg);
        let (c0, c1, _) = d.pr_window();
        let r = d.window_resources(c0, c1, CLOCK_REGION_ROWS);
        assert_eq!(r.luts, 17760);
        assert_eq!(r.ffs, 35520);
        assert_eq!(r.brams, 72);
        assert_eq!(r.dsps, 120);
    }

    #[test]
    fn zu9eg_pr_window_matches_table1() {
        let d = Device::new(DeviceKind::Zu9eg);
        let (c0, c1, _) = d.pr_window();
        let r = d.window_resources(c0, c1, CLOCK_REGION_ROWS);
        assert_eq!(r.luts, 32640);
        assert_eq!(r.ffs, 65280);
        assert_eq!(r.brams, 108);
        assert_eq!(r.dsps, 336);
    }

    #[test]
    fn chip_totals_near_real_silicon() {
        let d3 = Device::new(DeviceKind::Zu3eg).chip_resources();
        // Real ZU3EG: 70560 LUTs, 141120 FFs, 216 BRAM36, 360 DSP48.
        assert_eq!(d3.luts, 70560);
        assert_eq!(d3.ffs, 141120);
        assert_eq!(d3.brams, 216);
        assert_eq!(d3.dsps, 360);

        let d9 = Device::new(DeviceKind::Zu9eg).chip_resources();
        // Real ZU9EG: 274080 / 548160 / 912 / 2520. Allow ~2%.
        assert!((d9.luts as f64 - 274080.0).abs() / 274080.0 < 0.02, "{}", d9.luts);
        assert!((d9.dsps as f64 - 2520.0).abs() / 2520.0 < 0.02, "{}", d9.dsps);
        assert!((d9.brams as f64 - 912.0).abs() / 912.0 < 0.05, "{}", d9.brams);
    }

    #[test]
    fn interleave_counts_and_spread() {
        let cols = interleave(37, 6, 5);
        assert_eq!(cols.len(), 48);
        // Hard-block columns are spread out, not clumped: no run of 3+.
        for w in cols.windows(3) {
            assert!(
                w.iter().any(|&c| c == ColumnKind::Clb),
                "hard blocks clumped: {w:?}"
            );
        }
        // And the spread is genuinely even: every 12-column window holds
        // at least one hard block.
        for w in cols.windows(12) {
            assert!(w.iter().any(|&c| c != ColumnKind::Clb));
        }
    }

    #[test]
    fn pr_window_inside_chip() {
        for kind in [DeviceKind::Zu3eg, DeviceKind::Zu9eg] {
            let d = Device::new(kind);
            let (c0, c1, crs) = d.pr_window();
            assert!(c1 <= d.columns.len());
            assert!(c0 < c1);
            assert!(crs.end <= d.clock_regions());
            // PR window must not contain PS columns (not reconfigurable).
            assert!(d.columns[c0..c1].iter().all(|&c| c != ColumnKind::Ps));
        }
    }
}
