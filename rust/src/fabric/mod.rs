//! FPGA fabric model — the hardware substrate FOS runs on (§2.1.1).
//!
//! The paper's testbeds are Zynq UltraScale+ devices: ZU3EG behind the
//! Ultra96/UltraZed boards and ZU9EG behind the ZCU102. We model the
//! fabric the way the PR flow sees it: a 2-D grid of resource *columns*
//! (CLB / BRAM / DSP) crossed by clock regions of 60 rows, each column
//! segment carrying BUFCE_LEAF clock drivers and local routing wires.
//! Everything the paper's relocation rules (§4.1, requirements 1–4)
//! talk about — homogeneous resource footprints, identical interface
//! wire positions, regular clock-spline distribution, no static routing
//! through PR regions — is checkable on this model, and Table 1 falls
//! out of it by counting.

mod device;
mod floorplan;
mod clock;

pub use clock::ClockPlan;
pub use device::{Device, DeviceKind};
pub use floorplan::{Floorplan, PrRegion, Rect};

/// Height of one clock region in tile rows (UltraScale+ fabric).
pub const CLOCK_REGION_ROWS: usize = 60;

/// Resource column kinds, in the PR flow's eyes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ColumnKind {
    /// Logic column: 1 CLB per row = 8 LUTs + 16 flip-flops.
    Clb,
    /// Block-RAM column: 1 BRAM36 per 5 rows (12 per clock region).
    Bram,
    /// DSP column: 1 DSP48 per 2.5 rows (24 per clock region).
    Dsp,
    /// Processing system / IO / config — not reconfigurable.
    Ps,
}

impl ColumnKind {
    /// Configuration frames per column per clock region (bitstream model;
    /// ratios follow the UltraScale+ frame map shape).
    pub fn frames_per_region(self) -> usize {
        match self {
            ColumnKind::Clb => 36,
            ColumnKind::Bram => 6,
            ColumnKind::Dsp => 28,
            ColumnKind::Ps => 0,
        }
    }

    pub fn luts_per_row(self) -> usize {
        match self {
            ColumnKind::Clb => 8,
            _ => 0,
        }
    }

    pub fn ffs_per_row(self) -> usize {
        match self {
            ColumnKind::Clb => 16,
            _ => 0,
        }
    }
}

/// Aggregate resource counts — the currency of Table 1 and the region
/// allocator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Resources {
    pub luts: usize,
    pub ffs: usize,
    pub brams: usize,
    pub dsps: usize,
}

impl Resources {
    pub const ZERO: Resources = Resources { luts: 0, ffs: 0, brams: 0, dsps: 0 };

    pub fn add(&mut self, other: Resources) {
        self.luts += other.luts;
        self.ffs += other.ffs;
        self.brams += other.brams;
        self.dsps += other.dsps;
    }

    pub fn fits_in(&self, budget: &Resources) -> bool {
        self.luts <= budget.luts
            && self.ffs <= budget.ffs
            && self.brams <= budget.brams
            && self.dsps <= budget.dsps
    }

    pub fn scaled(&self, n: usize) -> Resources {
        Resources {
            luts: self.luts * n,
            ffs: self.ffs * n,
            brams: self.brams * n,
            dsps: self.dsps * n,
        }
    }

    /// LUT utilisation fraction against a budget (the paper's headline
    /// utilisation metric).
    pub fn lut_util(&self, budget: &Resources) -> f64 {
        self.luts as f64 / budget.luts.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resources_arithmetic() {
        let mut r = Resources { luts: 10, ffs: 20, brams: 1, dsps: 2 };
        r.add(Resources { luts: 5, ffs: 5, brams: 0, dsps: 1 });
        assert_eq!(r, Resources { luts: 15, ffs: 25, brams: 1, dsps: 3 });
        assert!(r.fits_in(&Resources { luts: 15, ffs: 25, brams: 1, dsps: 3 }));
        assert!(!r.fits_in(&Resources { luts: 14, ffs: 25, brams: 1, dsps: 3 }));
        assert_eq!(r.scaled(2).luts, 30);
    }

    #[test]
    fn column_kind_tables() {
        assert_eq!(ColumnKind::Clb.luts_per_row(), 8);
        assert_eq!(ColumnKind::Clb.ffs_per_row(), 16);
        assert_eq!(ColumnKind::Ps.frames_per_region(), 0);
        assert!(ColumnKind::Clb.frames_per_region() > ColumnKind::Bram.frames_per_region());
    }
}
