//! Clock distribution model — §4.1.1's BUFCE_LEAF discipline.
//!
//! The shell build prohibits all but a defined subset of BUFCE_LEAF
//! clock drivers inside PR regions, so every slot sees the same regular
//! clock-spline pattern and modules stay relocatable (requirement 3).
//! The static system then routes its own clocks *after* the prohibit
//! constraints are lifted, in a second incremental pass.

use super::{Device, PrRegion};

/// Which BUFCE_LEAF positions (column-relative) module clocks may use.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClockPlan {
    /// Allowed leaf positions as column offsets inside the PR window;
    /// one vertical clock spline per allowed leaf column.
    pub allowed_leaf_cols: Vec<usize>,
    /// Leaf row pitch: one leaf every `row_pitch` rows per spline.
    pub row_pitch: usize,
}

impl ClockPlan {
    /// The FOS default: a spline every 8 columns, a leaf every 30 rows
    /// (two per clock region) — regular across the whole PR window.
    pub fn fos_default(pr_cols: usize) -> ClockPlan {
        ClockPlan {
            allowed_leaf_cols: (0..pr_cols).step_by(8).collect(),
            row_pitch: 30,
        }
    }

    /// Leaves available to a module placed in `region`.
    pub fn leaves_in_region(&self, region: &PrRegion) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for &c in &self.allowed_leaf_cols {
            let col = region.bbox.c0 + c;
            if col >= region.bbox.c1 {
                continue;
            }
            let mut row = region.bbox.r0;
            while row < region.bbox.r1 {
                out.push((col, row));
                row += self.row_pitch;
            }
        }
        out
    }

    /// Requirement 3: the *relative* leaf pattern must be identical in
    /// every region.
    pub fn pattern_identical(&self, device: &Device, regions: &[PrRegion]) -> bool {
        let _ = device;
        let rel = |r: &PrRegion| -> Vec<(usize, usize)> {
            self.leaves_in_region(r)
                .into_iter()
                .map(|(c, row)| (c - r.bbox.c0, row - r.bbox.r0))
                .collect()
        };
        match regions.split_first() {
            None => true,
            Some((first, rest)) => {
                let base = rel(first);
                rest.iter().all(|r| rel(r) == base)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Device, DeviceKind, Floorplan};
    use super::*;

    #[test]
    fn default_plan_identical_across_regions() {
        for kind in [DeviceKind::Zu3eg, DeviceKind::Zu9eg] {
            let fp = Floorplan::standard(Device::new(kind));
            let (c0, c1, _) = fp.device.pr_window();
            let plan = ClockPlan::fos_default(c1 - c0);
            assert!(plan.pattern_identical(&fp.device, &fp.regions));
        }
    }

    #[test]
    fn leaves_cover_every_clock_region_segment() {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        let plan = ClockPlan::fos_default(48);
        let leaves = plan.leaves_in_region(&fp.regions[0]);
        // 6 splines (48/8) x 2 leaves per region (60/30).
        assert_eq!(leaves.len(), 12);
        assert!(leaves.iter().all(|&(c, r)| fp.regions[0].bbox.contains(c, r)));
    }

    #[test]
    fn irregular_plan_detected() {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        let plan = ClockPlan {
            allowed_leaf_cols: vec![0, 7, 9], // irregular spacing still OK:
            row_pitch: 45,                    // pattern is *relative*, so it
        };                                    // matches across aligned slots.
        assert!(plan.pattern_identical(&fp.device, &fp.regions));
        // Divergence: a narrower region loses the splines at cols 32/40.
        let plan = ClockPlan::fos_default(48);
        let mut fp2 = fp.clone();
        fp2.regions[1].bbox.c1 -= 16;
        assert!(!plan.pattern_identical(&fp2.device, &fp2.regions));
    }
}
