//! Floorplanning: static/PR partitioning and the relocation legality
//! rules of §4.1 (requirements 1–4).

use super::{ColumnKind, Device, Resources, CLOCK_REGION_ROWS};

/// A rectangular tile window: columns `[c0, c1)` × rows `[r0, r1)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rect {
    pub c0: usize,
    pub c1: usize,
    pub r0: usize,
    pub r1: usize,
}

impl Rect {
    pub fn cols(&self) -> usize {
        self.c1 - self.c0
    }

    pub fn rows(&self) -> usize {
        self.r1 - self.r0
    }

    pub fn contains(&self, col: usize, row: usize) -> bool {
        (self.c0..self.c1).contains(&col) && (self.r0..self.r1).contains(&row)
    }

    pub fn overlaps(&self, o: &Rect) -> bool {
        self.c0 < o.c1 && o.c0 < self.c1 && self.r0 < o.r1 && o.r0 < self.r1
    }
}

/// One partially reconfigurable slot.
#[derive(Debug, Clone)]
pub struct PrRegion {
    pub name: String,
    pub bbox: Rect,
    /// Interface tunnel rows (relative to `bbox.r0`) on the region's
    /// right edge — must be identical across regions (requirement 2).
    pub tunnel_rows: Vec<usize>,
}

impl PrRegion {
    /// The column-kind footprint: the sequence of resource columns under
    /// the bbox. Relocation requires footprints to be *identical*
    /// (requirement 1).
    pub fn footprint(&self, device: &Device) -> Vec<ColumnKind> {
        device.columns[self.bbox.c0..self.bbox.c1].to_vec()
    }

    pub fn resources(&self, device: &Device) -> Resources {
        device.window_resources(self.bbox.c0, self.bbox.c1, self.bbox.rows())
    }

    /// Clock-region-aligned? PR bitstream frames span whole clock-region
    /// column segments, so slots must align (requirement 3's precondition).
    pub fn is_clock_aligned(&self) -> bool {
        self.bbox.r0 % CLOCK_REGION_ROWS == 0 && self.bbox.r1 % CLOCK_REGION_ROWS == 0
    }
}

/// The static/PR split of one shell build.
#[derive(Debug, Clone)]
pub struct Floorplan {
    pub device: Device,
    pub regions: Vec<PrRegion>,
}

/// A relocation-legality violation (one of §4.1's requirements).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    FootprintMismatch { a: String, b: String },
    TunnelMismatch { a: String, b: String },
    NotClockAligned { region: String },
    Overlap { a: String, b: String },
    OutsideDevice { region: String },
    ContainsStatic { region: String },
}

impl Floorplan {
    /// Standard floorplan: stack identical slots vertically through the
    /// device's PR window, one clock region tall each.
    pub fn standard(device: Device) -> Floorplan {
        let (c0, c1, crs) = device.pr_window();
        let regions = crs
            .map(|cr| PrRegion {
                name: format!("pr{}", cr - device.pr_window().2.start),
                bbox: Rect {
                    c0,
                    c1,
                    r0: cr * CLOCK_REGION_ROWS,
                    r1: (cr + 1) * CLOCK_REGION_ROWS,
                },
                // Tunnel at rows 28..32 relative to the region base —
                // the pre-routed PR module interface position.
                tunnel_rows: vec![28, 29, 30, 31],
            })
            .collect();
        Floorplan { device, regions }
    }

    /// Check every §4.1 relocation requirement; empty vec = legal.
    pub fn check(&self) -> Vec<Violation> {
        let mut out = Vec::new();
        let dev_cols = self.device.columns.len();
        for r in &self.regions {
            if r.bbox.c1 > dev_cols || r.bbox.r1 > self.device.rows {
                out.push(Violation::OutsideDevice { region: r.name.clone() });
            }
            if !r.is_clock_aligned() {
                out.push(Violation::NotClockAligned { region: r.name.clone() });
            }
            if r.footprint(&self.device).iter().any(|&c| c == ColumnKind::Ps) {
                out.push(Violation::ContainsStatic { region: r.name.clone() });
            }
        }
        for (i, a) in self.regions.iter().enumerate() {
            for b in &self.regions[i + 1..] {
                if a.bbox.overlaps(&b.bbox) {
                    out.push(Violation::Overlap { a: a.name.clone(), b: b.name.clone() });
                }
                if a.footprint(&self.device) != b.footprint(&self.device) {
                    out.push(Violation::FootprintMismatch { a: a.name.clone(), b: b.name.clone() });
                }
                if a.tunnel_rows != b.tunnel_rows {
                    out.push(Violation::TunnelMismatch { a: a.name.clone(), b: b.name.clone() });
                }
            }
        }
        out
    }

    /// Can `n` regions starting at `first` be combined into one slot for
    /// a bigger module? Requires vertical adjacency (§3: "combine
    /// multiple adjacent partial regions").
    pub fn combinable(&self, first: usize, n: usize) -> bool {
        if n == 0 || first + n > self.regions.len() {
            return false;
        }
        self.regions[first..first + n]
            .windows(2)
            .all(|w| w[0].bbox.r1 == w[1].bbox.r0 && w[0].bbox.c0 == w[1].bbox.c0 && w[0].bbox.c1 == w[1].bbox.c1)
    }

    /// Resources left to the static shell (Table 1's complement).
    pub fn static_resources(&self) -> Resources {
        let chip = self.device.chip_resources();
        let mut pr = Resources::ZERO;
        for r in &self.regions {
            pr.add(r.resources(&self.device));
        }
        Resources {
            luts: chip.luts - pr.luts,
            ffs: chip.ffs - pr.ffs,
            brams: chip.brams - pr.brams,
            dsps: chip.dsps - pr.dsps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::DeviceKind;
    use super::*;

    #[test]
    fn standard_floorplans_are_legal() {
        for kind in [DeviceKind::Zu3eg, DeviceKind::Zu9eg] {
            let fp = Floorplan::standard(Device::new(kind));
            assert!(fp.check().is_empty(), "{:?}", fp.check());
        }
    }

    #[test]
    fn region_counts_match_paper() {
        let u96 = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        assert_eq!(u96.regions.len(), 3); // Ultra96/UltraZed: 3 slots
        let zcu = Floorplan::standard(Device::new(DeviceKind::Zu9eg));
        assert_eq!(zcu.regions.len(), 4); // ZCU102: 4 slots
    }

    #[test]
    fn all_regions_combinable() {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        assert!(fp.combinable(0, 1));
        assert!(fp.combinable(0, 2));
        assert!(fp.combinable(1, 2));
        assert!(fp.combinable(0, 3));
        assert!(!fp.combinable(1, 3)); // falls off the end
        assert!(!fp.combinable(0, 0));
    }

    #[test]
    fn footprint_mismatch_detected() {
        let mut fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        fp.regions[1].bbox.c0 += 1; // shift one slot — footprint now differs
        fp.regions[1].bbox.c1 += 1;
        let v = fp.check();
        assert!(v.iter().any(|x| matches!(x, Violation::FootprintMismatch { .. })), "{v:?}");
    }

    #[test]
    fn misaligned_region_detected() {
        let mut fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        fp.regions[0].bbox.r0 += 1;
        assert!(fp
            .check()
            .iter()
            .any(|x| matches!(x, Violation::NotClockAligned { .. })));
    }

    #[test]
    fn tunnel_mismatch_detected() {
        let mut fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        fp.regions[2].tunnel_rows = vec![0, 1, 2, 3];
        assert!(fp
            .check()
            .iter()
            .any(|x| matches!(x, Violation::TunnelMismatch { .. })));
    }

    #[test]
    fn overlap_detected() {
        let mut fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        fp.regions[1].bbox = fp.regions[0].bbox;
        assert!(fp
            .check()
            .iter()
            .any(|x| matches!(x, Violation::Overlap { .. })));
    }

    #[test]
    fn static_resources_complement() {
        let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        let stat = fp.static_resources();
        let chip = fp.device.chip_resources();
        // Paper: ~75.5% of Ultra96 LUTs go to accelerators.
        let pr_frac = 1.0 - stat.luts as f64 / chip.luts as f64;
        assert!((pr_frac - 0.7551).abs() < 0.001, "{pr_frac}");
    }
}
