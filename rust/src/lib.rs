//! # FOS — a modular FPGA operating system for dynamic workloads
//!
//! Full-system reproduction of Vaishnav et al., *"FOS: A Modular FPGA
//! Operating System for Dynamic Workloads"* (2020), on a simulated Zynq
//! UltraScale+ substrate. The paper's three usage modes are all here:
//!
//! 1. **static acceleration, single tenant** — [`driver::Cynq`]-style
//!    direct API,
//! 2. **dynamic (PR) acceleration, single tenant** — [`sched`] +
//!    [`reconfig`] under one user,
//! 3. **dynamic acceleration, multi tenant** — the [`daemon`], which
//!    arbitrates PR slots in time *and* space with resource-elastic
//!    scheduling (§4.4).
//!
//! Accelerator *compute* is real: each catalogued accelerator variant is
//! a JAX/Pallas program AOT-lowered to HLO text at build time
//! (`make artifacts`) and executed from Rust through the PJRT CPU client
//! ([`runtime`]). Python never runs on the request path.
//!
//! The FPGA itself is simulated (no silicon in this environment — see
//! DESIGN.md's substitution table): [`fabric`] models the device grid,
//! [`bitstream`] the frame-addressed configuration + BitMan relocation,
//! [`pnr`] the decoupled compilation flow, [`memsim`] the DDR/AXI
//! bandwidth behaviour, and [`reconfig`] the FPGA-manager latencies.
//!
//! ## Scheduler core
//!
//! Modes 2 and 3 share **one** scheduling brain:
//! [`sched::SchedCore`], a pure state machine owning region occupancy,
//! per-user queues, round-robin fairness and the elastic
//! placement/replacement/reuse/skip logic, pluggable through the
//! [`sched::SchedPolicy`] trait ([`sched::Elastic`] and
//! [`sched::Fixed`] ship as the seed policies).  The offline simulator
//! ([`sched::simulate`]) is a virtual-time discrete-event harness over
//! the core; the live daemon replays the *same* core against real
//! hardware effects (region-anchored loads through
//! [`driver::Cynq::load_accelerator_at`], PJRT compute, virtual-clock
//! completions), so for one trace both paths produce identical
//! decision sequences and report identical
//! [`sched::SchedCounters`] — see `tests/sched_parity.rs`.  Each
//! decision is a [`sched::Decision`] (user, accelerator, variant,
//! anchor, span, reuse-vs-reconfigure, replication flag, and a
//! [`sched::DecisionKind`] distinguishing fresh runs from
//! checkpoint/restore `Preempt`/`Resume` steps); tenants pick
//! their policy per connection via `FpgaRpc::set_policy`, and new
//! policies (fairness, preemption, ...) are `SchedPolicy`
//! implementations registered with [`sched::SchedCore::register_policy`]
//! — not forks of the dispatch loops.  In front of the core sits the
//! tenant-aware **admission pipeline** ([`sched::AdmissionPipeline`]):
//! per-tenant bounded queues with structured busy backpressure,
//! weighted deficit-round-robin batched ingest and token-bucket
//! in-flight quotas, driven identically by the simulator and the
//! daemon (whose wire protocol splits blocking `run` into async
//! `submit`→ticket plus `wait`/`poll`/`completions`); the
//! [`sched::FairShare`] seed policy consumes the same tenant plumbing
//! to bound any tenant's service deficit.  Above the per-board core,
//! the **cluster layer** ([`sched::ClusterCore`]) shards the same machinery
//! over N heterogeneous boards behind a pluggable
//! [`sched::PlacementPolicy`] (round-robin / least-loaded /
//! bitstream-locality with work stealing), driven by
//! [`sched::simulate_cluster`] offline and `Daemon::start_cluster`
//! live.  The core/policy/sim/daemon split, the decision lifecycle,
//! the preemption state machine and the cluster layer are documented
//! in `src/sched/ARCHITECTURE.md`.

pub mod json;
pub mod fabric;
pub mod bitstream;
pub mod pnr;
pub mod shell;
pub mod registry;
pub mod driver;
pub mod memsim;
pub mod reconfig;
pub mod runtime;
pub mod accel;
pub mod sched;
pub mod daemon;
pub mod metrics;
pub mod testutil;

/// Workspace-root-relative artifact directory (`make artifacts` output).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("FOS_ARTIFACTS") {
        return dir.into();
    }
    // Walk up from the current dir to find `artifacts/manifest.json` —
    // works from the repo root, test binaries and bench binaries alike.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}
