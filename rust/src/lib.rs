//! # FOS — a modular FPGA operating system for dynamic workloads
//!
//! Full-system reproduction of Vaishnav et al., *"FOS: A Modular FPGA
//! Operating System for Dynamic Workloads"* (2020), on a simulated Zynq
//! UltraScale+ substrate. The paper's three usage modes are all here:
//!
//! 1. **static acceleration, single tenant** — [`cynq`]-style direct API,
//! 2. **dynamic (PR) acceleration, single tenant** — [`sched`] +
//!    [`reconfig`] under one user,
//! 3. **dynamic acceleration, multi tenant** — the [`daemon`], which
//!    arbitrates PR slots in time *and* space with resource-elastic
//!    scheduling (§4.4).
//!
//! Accelerator *compute* is real: each catalogued accelerator variant is
//! a JAX/Pallas program AOT-lowered to HLO text at build time
//! (`make artifacts`) and executed from Rust through the PJRT CPU client
//! ([`runtime`]). Python never runs on the request path.
//!
//! The FPGA itself is simulated (no silicon in this environment — see
//! DESIGN.md's substitution table): [`fabric`] models the device grid,
//! [`bitstream`] the frame-addressed configuration + BitMan relocation,
//! [`pnr`] the decoupled compilation flow, [`memsim`] the DDR/AXI
//! bandwidth behaviour, and [`reconfig`] the FPGA-manager latencies.

pub mod json;
pub mod fabric;
pub mod bitstream;
pub mod pnr;
pub mod shell;
pub mod registry;
pub mod driver;
pub mod memsim;
pub mod reconfig;
pub mod runtime;
pub mod accel;
pub mod sched;
pub mod daemon;
pub mod metrics;
pub mod testutil;

/// Workspace-root-relative artifact directory (`make artifacts` output).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("FOS_ARTIFACTS") {
        return dir.into();
    }
    // Walk up from the current dir to find `artifacts/manifest.json` —
    // works from the repo root, test binaries and bench binaries alike.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}
