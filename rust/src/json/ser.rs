//! Deterministic JSON serialisation (compact + pretty).

use super::Value;

/// Compact form — the daemon wire format.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, None, 0, &mut out);
    out
}

/// Pretty form — on-disk descriptors and registry files.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, Some(2), 0, &mut out);
    out
}

fn write_value(v: &Value, indent: Option<usize>, level: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => write_f64(*f, out),
        Value::Str(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (k, item) in items.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                newline(indent, level + 1, out);
                write_value(item, indent, level + 1, out);
            }
            newline(indent, level, out);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (k, (key, val)) in map.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                newline(indent, level + 1, out);
                write_string(key, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, indent, level + 1, out);
            }
            newline(indent, level, out);
            out.push('}');
        }
    }
}

fn newline(indent: Option<usize>, level: usize, out: &mut String) {
    if let Some(n) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(n * level));
    }
}

fn write_f64(f: f64, out: &mut String) {
    if f.is_nan() || f.is_infinite() {
        // JSON has no NaN/Inf; null is the conventional degradation.
        out.push_str("null");
    } else if f == f.trunc() && f.abs() < 1e15 {
        // Keep a `.0` so the value round-trips as Float, not Int.
        out.push_str(&format!("{f:.1}"));
    } else {
        out.push_str(&format!("{f}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::{arr, b, f, i, obj, parse, s};
    use super::*;

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = obj(vec![
            ("name", s("vadd")),
            ("regions", arr(vec![s("pr0"), s("pr1")])),
            ("luts", i(1420)),
            ("util", f(0.33)),
            ("rtl", b(false)),
            ("meta", Value::Null),
        ]);
        for text in [to_string(&v), to_string_pretty(&v)] {
            assert_eq!(parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn float_keeps_dot_zero() {
        assert_eq!(to_string(&f(2.0)), "2.0");
        assert_eq!(parse("2.0").unwrap(), f(2.0));
        assert_eq!(to_string(&f(0.25)), "0.25");
    }

    #[test]
    fn control_chars_escaped() {
        let v = s("a\u{0001}b\n");
        let text = to_string(&v);
        assert_eq!(text, "\"a\\u0001b\\n\"");
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn nan_degrades_to_null() {
        assert_eq!(to_string(&f(f64::NAN)), "null");
    }

    #[test]
    fn empty_collections() {
        assert_eq!(to_string(&arr(vec![])), "[]");
        assert_eq!(to_string(&obj(vec![])), "{}");
        assert_eq!(to_string_pretty(&arr(vec![])), "[]");
    }
}
