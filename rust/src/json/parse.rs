//! Recursive-descent JSON parser (strict RFC 8259 subset).

use super::Value;
use std::collections::BTreeMap;
use std::fmt;

/// Parse failure with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(format!("unexpected `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, text: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected `{text}`")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        self.depth += 1;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
        self.depth -= 1;
        Ok(Value::Object(map))
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        self.depth += 1;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => break,
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
        self.depth -= 1;
        Ok(Value::Array(items))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate — require the low half.
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(c)
                                .ok_or_else(|| self.err("bad codepoint"))?
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: validate via str machinery.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8 byte")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8 sequence"));
                    }
                    let slice = &self.bytes[start..start + len];
                    let st = std::str::from_utf8(slice)
                        .map_err(|_| self.err("invalid utf-8 sequence"))?;
                    out.push_str(st);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit required after `.`"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit required in exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Value::Float)
            .map_err(|_| self.err("number out of range"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listing1_shell_descriptor() {
        // The paper's Listing 1, verbatim structure.
        let text = r#"{
            "name": "Ultra96_100MHz_2",
            "bitfile": "Ultra96_100MHz_2.bin",
            "regions": [
              {"name": "pr0", "blank": "Blanking_slot_0.bin",
               "bridge": "0xa0010000", "addr": "0xa0000000"},
              {"name": "pr1", "blank": "Blanking_slot_1.bin",
               "bridge": "0xa0020000", "addr": "0xa0001000"}
            ]
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("name").as_str(), Some("Ultra96_100MHz_2"));
        assert_eq!(v.get("regions").as_array().unwrap().len(), 2);
        assert_eq!(
            v.get("regions").idx(1).get("bridge").as_str(),
            Some("0xa0020000")
        );
    }

    #[test]
    fn escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\Aé😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé😀"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("0").unwrap(), Value::Int(0));
        assert_eq!(parse("-12").unwrap(), Value::Int(-12));
        assert_eq!(parse("3.25").unwrap(), Value::Float(3.25));
        assert_eq!(parse("-1e-3").unwrap(), Value::Float(-0.001));
        assert_eq!(parse("1E2").unwrap(), Value::Float(100.0));
        assert!(parse("01").is_err());
        assert!(parse("1.").is_err());
        assert!(parse("+1").is_err());
        assert!(parse("--1").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1,}").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("[1] x").is_err());
        assert!(parse("\"\\q\"").is_err());
        assert!(parse("\"\\ud800\"").is_err()); // unpaired surrogate
    }

    #[test]
    fn deep_nesting_bounded() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(64) + &"]".repeat(64);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn error_offsets() {
        let e = parse("[1, 2, x]").unwrap_err();
        assert_eq!(e.offset, 7);
    }
}
