//! Minimal, dependency-free JSON (the offline vendor set has no serde).
//!
//! FOS leans on JSON in three places, all paper-mandated:
//! - the logical hardware abstraction (§4.2): shell + accelerator
//!   descriptors (Listings 1–2),
//! - `artifacts/manifest.json` written by the python AOT pipeline,
//! - the daemon RPC wire format (our gRPC stand-in, §4.4.1).
//!
//! The implementation is a strict RFC 8259 subset: UTF-8 input, `\uXXXX`
//! escapes (incl. surrogate pairs), i64/f64 numbers. Serialisation is
//! deterministic (object keys keep insertion order).

mod parse;
mod ser;

pub use parse::{parse, ParseError};

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Integral number (no fraction/exponent in the source).
    Int(i64),
    /// Any other number.
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    /// Sorted map — deterministic output, cheap lookup.
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Value::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Value {
        const NULL: Value = Value::Null;
        match self {
            Value::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; `Value::Null` out of range.
    pub fn idx(&self, i: usize) -> &Value {
        const NULL: Value = Value::Null;
        match self {
            Value::Array(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Typed accessors that error with a path-labelled message — the
    /// registry uses these so a malformed descriptor names its field.
    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.get(key)
            .as_str()
            .ok_or_else(|| format!("missing/invalid string field `{key}`"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, String> {
        self.get(key)
            .as_u64()
            .ok_or_else(|| format!("missing/invalid integer field `{key}`"))
    }

    pub fn req_array(&self, key: &str) -> Result<&[Value], String> {
        self.get(key)
            .as_array()
            .ok_or_else(|| format!("missing/invalid array field `{key}`"))
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&ser::to_string(self))
    }
}

/// Builder helpers so call-sites stay terse without serde derive.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Object(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}

pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

pub fn i(v: i64) -> Value {
    Value::Int(v)
}

pub fn f(v: f64) -> Value {
    Value::Float(v)
}

pub fn b(v: bool) -> Value {
    Value::Bool(v)
}

pub use ser::{to_string, to_string_pretty};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = parse(r#"{"a": 1, "b": [true, "x"], "c": 2.5}"#).unwrap();
        assert_eq!(v.get("a").as_i64(), Some(1));
        assert_eq!(v.get("b").idx(0).as_bool(), Some(true));
        assert_eq!(v.get("b").idx(1).as_str(), Some("x"));
        assert_eq!(v.get("c").as_f64(), Some(2.5));
        assert!(v.get("missing").is_null());
        assert!(v.get("a").get("nested").is_null());
        assert!(v.idx(0).is_null());
    }

    #[test]
    fn req_accessors_error_messages() {
        let v = parse(r#"{"name": "x"}"#).unwrap();
        assert_eq!(v.req_str("name").unwrap(), "x");
        let err = v.req_u64("addr").unwrap_err();
        assert!(err.contains("addr"), "{err}");
    }

    #[test]
    fn int_vs_float_discrimination() {
        let v = parse("[1, 1.0, -3, 1e2]").unwrap();
        assert_eq!(v.idx(0), &Value::Int(1));
        assert_eq!(v.idx(1), &Value::Float(1.0));
        assert_eq!(v.idx(2), &Value::Int(-3));
        assert_eq!(v.idx(3), &Value::Float(100.0));
    }

    #[test]
    fn builders_roundtrip() {
        let v = obj(vec![
            ("name", s("pr0")),
            ("addr", i(0xa000_0000)),
            ("ok", b(true)),
            ("list", arr(vec![i(1), i(2)])),
        ]);
        let text = to_string(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }
}
