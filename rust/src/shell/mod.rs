//! FPGA shells — the OS-kernel of the hardware infrastructure (§2.1.1,
//! §4.1.1) plus bus virtualisation (§4.1.2).

mod build;
mod bus;

pub use build::{Shell, ShellBoard};
pub use bus::{
    AxiInterface, BusAdaptor, BusService, WrapMode, SHELL_MASTER_BITS, SHELL_LITE_BITS,
};
