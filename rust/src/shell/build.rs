//! Shell builder: the ZUCL-2.0-style static systems for the three
//! boards, with the Listing-1 JSON descriptor as their logical face.

use crate::fabric::{ClockPlan, Device, DeviceKind, Floorplan, Resources};
use crate::json::{arr, obj, s, Value};

/// The three boards the paper evaluates on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShellBoard {
    Ultra96,
    UltraZed,
    Zcu102,
}

impl ShellBoard {
    pub fn name(self) -> &'static str {
        match self {
            ShellBoard::Ultra96 => "Ultra96",
            ShellBoard::UltraZed => "UltraZed",
            ShellBoard::Zcu102 => "ZCU102",
        }
    }

    pub fn device_kind(self) -> DeviceKind {
        match self {
            ShellBoard::Ultra96 | ShellBoard::UltraZed => DeviceKind::Zu3eg,
            ShellBoard::Zcu102 => DeviceKind::Zu9eg,
        }
    }

    /// High-performance AXI ports wired to PR regions (§5.3): the
    /// Ultra96 shell exposes HP0, HP1, HP3; the ZCU102 shell HP0–HP3.
    pub fn axi_ports(self) -> &'static [&'static str] {
        match self {
            ShellBoard::Ultra96 | ShellBoard::UltraZed => &["HP0", "HP1", "HP3"],
            ShellBoard::Zcu102 => &["HP0", "HP1", "HP2", "HP3"],
        }
    }

    pub fn all() -> [ShellBoard; 3] {
        [ShellBoard::Ultra96, ShellBoard::UltraZed, ShellBoard::Zcu102]
    }
}

/// A built shell: floorplan + clocking + the address map the drivers use.
#[derive(Debug, Clone)]
pub struct Shell {
    pub board: ShellBoard,
    pub name: String,
    pub clock_mhz: u32,
    pub floorplan: Floorplan,
    pub clock_plan: ClockPlan,
    /// Per-region accelerator base addresses (Listing 1 `addr`).
    pub region_addrs: Vec<u64>,
    /// Per-region PR decoupler bridge addresses (Listing 1 `bridge`).
    pub bridge_addrs: Vec<u64>,
}

impl Shell {
    /// Build the standard 100 MHz shell for a board.
    pub fn build(board: ShellBoard) -> Shell {
        let device = Device::new(board.device_kind());
        let floorplan = Floorplan::standard(device);
        debug_assert!(floorplan.check().is_empty());
        let (c0, c1, _) = floorplan.device.pr_window();
        let clock_plan = ClockPlan::fos_default(c1 - c0);
        let n = floorplan.regions.len();
        Shell {
            name: format!("{}_100MHz_2", board.name()),
            board,
            clock_mhz: 100,
            floorplan,
            clock_plan,
            region_addrs: (0..n).map(|k| 0xa000_0000 + 0x1000 * k as u64).collect(),
            bridge_addrs: (0..n).map(|k| 0xa001_0000 + 0x10000 * k as u64).collect(),
        }
    }

    pub fn region_count(&self) -> usize {
        self.floorplan.regions.len()
    }

    /// Resources of one PR region (all identical by construction).
    pub fn region_resources(&self) -> Resources {
        self.floorplan.regions[0].resources(&self.floorplan.device)
    }

    /// Table 1's rows: per-region and total accelerator utilisation
    /// fractions against the chip.
    pub fn table1(&self) -> Table1 {
        let chip = self.floorplan.device.chip_resources();
        let region = self.region_resources();
        let n = self.region_count();
        let frac = |a: usize, b: usize| a as f64 / b as f64;
        Table1 {
            region,
            per_region_pct: [
                100.0 * frac(region.luts, chip.luts),
                100.0 * frac(region.ffs, chip.ffs),
                100.0 * frac(region.brams, chip.brams),
                100.0 * frac(region.dsps, chip.dsps),
            ],
            total_pct: [
                100.0 * frac(region.luts * n, chip.luts),
                100.0 * frac(region.ffs * n, chip.ffs),
                100.0 * frac(region.brams * n, chip.brams),
                100.0 * frac(region.dsps * n, chip.dsps),
            ],
        }
    }

    /// The Listing-1 JSON descriptor.
    pub fn descriptor(&self) -> Value {
        obj(vec![
            ("name", s(self.name.clone())),
            ("bitfile", s(format!("{}.bin", self.name))),
            (
                "regions",
                arr(self
                    .floorplan
                    .regions
                    .iter()
                    .enumerate()
                    .map(|(k, r)| {
                        obj(vec![
                            ("name", s(r.name.clone())),
                            ("blank", s(format!("Blanking_slot_{k}.bin"))),
                            ("bridge", s(format!("{:#x}", self.bridge_addrs[k]))),
                            ("addr", s(format!("{:#x}", self.region_addrs[k]))),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

/// Table 1 measurement bundle.
#[derive(Debug, Clone)]
pub struct Table1 {
    pub region: Resources,
    /// [LUT, FF, BRAM, DSP] chip-% per region.
    pub per_region_pct: [f64; 4],
    /// [LUT, FF, BRAM, DSP] chip-% across all regions.
    pub total_pct: [f64; 4],
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_region_resources() {
        let u96 = Shell::build(ShellBoard::Ultra96);
        assert_eq!(u96.region_count(), 3);
        let r = u96.region_resources();
        assert_eq!((r.luts, r.ffs, r.brams, r.dsps), (17760, 35520, 72, 120));

        let zcu = Shell::build(ShellBoard::Zcu102);
        assert_eq!(zcu.region_count(), 4);
        let r = zcu.region_resources();
        assert_eq!((r.luts, r.ffs, r.brams, r.dsps), (32640, 65280, 108, 336));
    }

    #[test]
    fn table1_percentages_near_paper() {
        // Paper: ZCU102 ≈11.7–13.3% per region, 46.8–53.2% total;
        // Ultra96 ≈25.17% per region, 75.51% total (LUTs).
        let zcu = Shell::build(ShellBoard::Zcu102).table1();
        assert!((zcu.per_region_pct[0] - 11.7).abs() < 0.5, "{:?}", zcu.per_region_pct);
        assert!((zcu.total_pct[0] - 46.8).abs() < 2.0);
        assert!((zcu.per_region_pct[3] - 13.3).abs() < 0.1);

        let u96 = Shell::build(ShellBoard::Ultra96).table1();
        assert!((u96.per_region_pct[0] - 25.17).abs() < 0.01);
        assert!((u96.total_pct[0] - 75.51).abs() < 0.01);
    }

    #[test]
    fn ultrazed_shares_zu3eg_shell_shape() {
        let uz = Shell::build(ShellBoard::UltraZed);
        let u96 = Shell::build(ShellBoard::Ultra96);
        assert_eq!(uz.region_count(), u96.region_count());
        assert_eq!(uz.region_resources(), u96.region_resources());
        assert_ne!(uz.name, u96.name);
    }

    #[test]
    fn descriptor_matches_listing1() {
        let shell = Shell::build(ShellBoard::Ultra96);
        let d = shell.descriptor();
        assert_eq!(d.req_str("name").unwrap(), "Ultra96_100MHz_2");
        assert_eq!(d.req_str("bitfile").unwrap(), "Ultra96_100MHz_2.bin");
        let regions = d.req_array("regions").unwrap();
        assert_eq!(regions.len(), 3);
        assert_eq!(regions[0].req_str("addr").unwrap(), "0xa0000000");
        assert_eq!(regions[1].req_str("addr").unwrap(), "0xa0001000");
        assert_eq!(regions[1].req_str("bridge").unwrap(), "0xa0020000");
        assert_eq!(regions[2].req_str("blank").unwrap(), "Blanking_slot_2.bin");
        // Round-trips through our JSON.
        let text = crate::json::to_string_pretty(&d);
        assert_eq!(crate::json::parse(&text).unwrap(), d);
    }

    #[test]
    fn axi_port_lists() {
        assert_eq!(ShellBoard::Ultra96.axi_ports(), &["HP0", "HP1", "HP3"]);
        assert_eq!(ShellBoard::Zcu102.axi_ports().len(), 4);
    }
}
