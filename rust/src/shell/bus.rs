//! Bus virtualisation (§4.1.2, Table 2).
//!
//! The shell exposes one fixed physical interface per PR region — a
//! 32-bit AXI4-Lite slave (control) and a 128-bit AXI4 master (memory).
//! Modules that speak anything else (narrower AXI, AXI-Stream with or
//! without a DMA engine) get a *bus adaptor*: vendor IP blocks
//! (interconnect / MM2S / DMA / control registers) parameterised and
//! stitched either at design time (logical wrapper, costs only what it
//! uses) or at run time (a pre-allocated partial region of fixed size —
//! the physical-level overhead column of Table 2).

use crate::fabric::Resources;

/// The shell-side fixed interface widths (§4.1.2).
pub const SHELL_LITE_BITS: u32 = 32;
pub const SHELL_MASTER_BITS: u32 = 128;

/// What a module's native interface looks like.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AxiInterface {
    /// AXI4 memory-mapped master of a given data width (module has its
    /// own DMA).
    Master { bits: u32 },
    /// AXI4-Stream of a given width; `has_dma` says whether the module
    /// embeds its own data mover.
    Stream { bits: u32, has_dma: bool },
    /// Control-only module (AXI-Lite slave, no data path).
    LiteOnly,
}

/// Adaptor services the wrapper instantiates (Table 2's middle column).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BusService {
    /// Width/protocol conversion between AXI4 masters.
    AxiInterconnect,
    /// Memory-mapped-to-stream bridge.
    Mm2s,
    /// Full DMA engine (for DMA-less stream modules).
    Dma,
    /// Control register block.
    ControlRegs,
}

impl BusService {
    /// Logical-level (design-time wrapper) resource cost of one service.
    /// Calibrated so the two Table 2 configurations come out exactly:
    /// interconnect alone = 153/284/0, ctrl+MM2S+DMA = 1952/2694/2.5.
    pub fn resources(self) -> (usize, usize, f64) {
        match self {
            BusService::AxiInterconnect => (153, 284, 0.0),
            BusService::Mm2s => (612, 901, 0.5),
            BusService::Dma => (1188, 1602, 2.0),
            BusService::ControlRegs => (152, 191, 0.0),
        }
    }
}

/// Design-time vs run-time stitching (§4.1.2, Figure 10).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WrapMode {
    /// Wrapper compiled into the module: pays only the logical cost.
    DesignTime,
    /// Pre-built adaptor bitstream stitched by PR at run time: pays a
    /// fixed pre-allocated adaptor region (Table 2 "physical level").
    Runtime,
}

/// The pre-allocated adaptor region size at the physical level
/// (Table 2): 2400 LUTs / 4800 FFs / 12 BRAMs.
pub const PHYSICAL_PREALLOC: Resources = Resources {
    luts: 2400,
    ffs: 4800,
    brams: 12,
    dsps: 0,
};

/// A configured bus adaptor between a module interface and the shell.
#[derive(Debug, Clone)]
pub struct BusAdaptor {
    pub module_if: AxiInterface,
    pub services: Vec<BusService>,
    pub mode: WrapMode,
}

impl BusAdaptor {
    /// Choose the services a module interface needs (§4.1.2's automatic
    /// parameterisation). `None` means the module matches the shell
    /// natively and no adaptor is required at all — "an adaptor is only
    /// integrated into a module if needed".
    pub fn for_interface(module_if: AxiInterface, mode: WrapMode) -> Option<BusAdaptor> {
        let services = match module_if {
            AxiInterface::Master { bits } if bits == SHELL_MASTER_BITS => return None,
            AxiInterface::Master { .. } => vec![BusService::AxiInterconnect],
            AxiInterface::Stream { has_dma: true, .. } => {
                vec![BusService::ControlRegs, BusService::Mm2s]
            }
            AxiInterface::Stream { has_dma: false, .. } => {
                vec![BusService::ControlRegs, BusService::Mm2s, BusService::Dma]
            }
            AxiInterface::LiteOnly => return None,
        };
        Some(BusAdaptor { module_if, services, mode })
    }

    /// Logical-level cost: the sum of the instantiated services.
    pub fn logical_resources(&self) -> Resources {
        let mut luts = 0;
        let mut ffs = 0;
        let mut brams = 0.0;
        for s in &self.services {
            let (l, f, b) = s.resources();
            luts += l;
            ffs += f;
            brams += b;
        }
        Resources { luts, ffs, brams: brams.ceil() as usize, dsps: 0 }
    }

    /// BRAMs with the half-BRAM18 granularity Table 2 reports (2.5).
    pub fn logical_brams_frac(&self) -> f64 {
        self.services.iter().map(|s| s.resources().2).sum()
    }

    /// What the adaptor actually occupies on the fabric.
    pub fn physical_resources(&self) -> Resources {
        match self.mode {
            WrapMode::DesignTime => self.logical_resources(),
            WrapMode::Runtime => PHYSICAL_PREALLOC,
        }
    }

    /// Unused fraction of the pre-allocation (the paper's "448 LUTs
    /// (18%)" observation is `1 -` this for the dense configuration).
    pub fn prealloc_waste_luts(&self) -> usize {
        match self.mode {
            WrapMode::DesignTime => 0,
            WrapMode::Runtime => {
                PHYSICAL_PREALLOC.luts.saturating_sub(self.logical_resources().luts)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_interconnect_configuration() {
        // Row 1: 32-bit AXI master module behind the 128-bit shell port.
        let a = BusAdaptor::for_interface(
            AxiInterface::Master { bits: 32 },
            WrapMode::Runtime,
        )
        .unwrap();
        assert_eq!(a.services, vec![BusService::AxiInterconnect]);
        let r = a.logical_resources();
        assert_eq!((r.luts, r.ffs), (153, 284));
        assert_eq!(a.logical_brams_frac(), 0.0);
        let p = a.physical_resources();
        assert_eq!((p.luts, p.ffs, p.brams), (2400, 4800, 12));
    }

    #[test]
    fn table2_stream_dma_configuration() {
        // Row 2: 32-bit AXI-Stream module without DMA → ctrl + MM2S + DMA.
        let a = BusAdaptor::for_interface(
            AxiInterface::Stream { bits: 32, has_dma: false },
            WrapMode::Runtime,
        )
        .unwrap();
        let r = a.logical_resources();
        assert_eq!((r.luts, r.ffs), (1952, 2694));
        assert_eq!(a.logical_brams_frac(), 2.5);
        // Paper: only ~448 LUTs of the 2400 pre-allocation stay unused
        // for this configuration (18%).
        assert_eq!(a.prealloc_waste_luts(), 448);
        assert!((a.prealloc_waste_luts() as f64 / 2400.0 - 0.18).abs() < 0.01);
    }

    #[test]
    fn native_modules_need_no_adaptor() {
        assert!(BusAdaptor::for_interface(
            AxiInterface::Master { bits: 128 },
            WrapMode::DesignTime
        )
        .is_none());
        assert!(BusAdaptor::for_interface(AxiInterface::LiteOnly, WrapMode::DesignTime).is_none());
    }

    #[test]
    fn stream_with_dma_skips_dma_service() {
        let a = BusAdaptor::for_interface(
            AxiInterface::Stream { bits: 64, has_dma: true },
            WrapMode::DesignTime,
        )
        .unwrap();
        assert!(!a.services.contains(&BusService::Dma));
        assert!(a.services.contains(&BusService::Mm2s));
        // Design-time wrapper pays only what it uses.
        assert_eq!(a.physical_resources(), a.logical_resources());
        assert_eq!(a.prealloc_waste_luts(), 0);
    }

    #[test]
    fn runtime_mode_fits_prealloc_region() {
        // Every adaptor configuration must fit the pre-allocated region.
        for m in [
            AxiInterface::Master { bits: 32 },
            AxiInterface::Master { bits: 64 },
            AxiInterface::Stream { bits: 32, has_dma: false },
            AxiInterface::Stream { bits: 128, has_dma: true },
        ] {
            if let Some(a) = BusAdaptor::for_interface(m, WrapMode::Runtime) {
                assert!(a.logical_resources().fits_in(&PHYSICAL_PREALLOC), "{m:?}");
            }
        }
    }
}
