//! `fos` — the FOS leader binary.
//!
//! Subcommands (hand-rolled parsing; no clap in the offline vendor set):
//!
//! ```text
//! fos daemon [--socket PATH] [--board ultra96|ultrazed|zcu102]
//!            [--boards B1,B2,...] [--placement round-robin|least-loaded|locality]
//!            [--policy elastic|fixed|quantum|elastic-pre|fair]
//!            [--queue-cap N] [--quantum-tiles N] [--max-conns N]
//!            [--reactor-shards N]
//!            [--fault-plan SPEC] [--tenants T1,T2,...] [--bw-partition]
//! fos run    [--socket PATH] --accel NAME [--requests N]
//!            [--tenant NAME] [--token TOK] [--weight W] [--max-inflight N] [--async]
//! fos info   [--board BOARD]         # shell + catalog + Table 1 summary
//! fos registry [--board BOARD] --out FILE
//! ```
//!
//! `--boards` starts a multi-fabric cluster daemon (one `Cynq` per
//! board, heterogeneous mixes welcome) with `--placement` routing
//! requests across boards (default: locality).  `--queue-cap` /
//! `--quantum-tiles` tune the tenant-aware admission pipeline (bounded
//! per-tenant queues with structured busy backpressure; finite quantum
//! arms weighted DRR ingest), `--max-conns` caps the connection table,
//! and `--reactor-shards N` runs the network plane as N reactor
//! threads fed by a dedicated acceptor (default 1: the single-threaded
//! reactor; the dispatcher is single-threaded either way).
//! `fos run --tenant acme --weight 3` binds the connection to a named
//! QoS session; `--async` submits for a ticket and drains it through
//! the wait RPC explicitly.  `--fault-plan` arms deterministic fault
//! injection (board outages, reconfiguration failures, transient run
//! errors — see `fos::sched::FaultPlan::parse` for the spec format)
//! for failover soak testing against the live daemon.  `--tenants`
//! switches the daemon to authenticated mode (per-tenant bearer tokens
//! plus an admin token, printed once at startup; `fos run --token`
//! presents one), and `--bw-partition` arms weighted memory-bandwidth
//! partitioning between tenant sessions.

use fos::accel::Catalog;
use fos::daemon::{Daemon, FpgaRpc, Job};
use fos::metrics::Table;
use fos::registry::Registry;
use fos::shell::{Shell, ShellBoard};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let get = |flag: &str| -> Option<String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|p| args.get(p + 1))
            .cloned()
    };
    let parse_board = |name: &str| -> ShellBoard {
        match name {
            "ultra96" => ShellBoard::Ultra96,
            "ultrazed" => ShellBoard::UltraZed,
            "zcu102" => ShellBoard::Zcu102,
            other => {
                eprintln!("unknown board {other:?}");
                std::process::exit(2);
            }
        }
    };
    let board = parse_board(get("--board").as_deref().unwrap_or("ultra96"));
    let socket = get("--socket").unwrap_or_else(|| "/tmp/fos-daemon.sock".to_string());

    match cmd {
        "daemon" => {
            let catalog =
                Catalog::load_default().expect("artifacts missing — run `make artifacts`");
            let n = catalog.accelerators.len();
            // `--boards b1,b2,...` starts a multi-fabric cluster; the
            // single `--board` is a one-board cluster.
            let boards: Vec<ShellBoard> = match get("--boards") {
                Some(list) => list.split(',').map(|b| parse_board(b.trim())).collect(),
                None => vec![board],
            };
            let placement = match get("--placement").as_deref().unwrap_or("locality") {
                "round-robin" => fos::sched::PlacementKind::RoundRobin,
                "least-loaded" => fos::sched::PlacementKind::LeastLoaded,
                "locality" => fos::sched::PlacementKind::Locality,
                other => {
                    eprintln!("unknown placement {other:?}");
                    std::process::exit(2);
                }
            };
            let policy = match get("--policy").as_deref().unwrap_or("elastic") {
                "elastic" => fos::sched::Policy::Elastic,
                "fixed" => fos::sched::Policy::Fixed,
                "quantum" => fos::sched::Policy::Quantum,
                "elastic-pre" => fos::sched::Policy::ElasticPreempt,
                "fair" => fos::sched::Policy::FairShare,
                other => {
                    eprintln!("unknown policy {other:?}");
                    std::process::exit(2);
                }
            };
            let mut admission = fos::sched::AdmissionConfig::default();
            if let Some(cap) = get("--queue-cap").and_then(|v| v.parse().ok()) {
                admission.queue_cap = cap;
            }
            if let Some(q) = get("--quantum-tiles").and_then(|v| v.parse().ok()) {
                admission.quantum_tiles = q;
            }
            if args.iter().any(|a| a == "--bw-partition") {
                admission.bw_partition = true;
            }
            let max_conns: usize = get("--max-conns")
                .and_then(|v| v.parse().ok())
                .unwrap_or(fos::daemon::DEFAULT_MAX_CONNECTIONS);
            // `--reactor-shards 4` spreads connection I/O over four
            // reactor threads fed by one acceptor; scheduling stays on
            // the single dispatcher thread regardless.
            let reactor_shards: usize = get("--reactor-shards")
                .and_then(|v| v.parse().ok())
                .filter(|&n| n >= 1)
                .unwrap_or(1);
            // `--fault-plan seed=7,reconfig=0.05,down=1@50+40` arms
            // deterministic fault injection for soak testing: board
            // outages + reconfig/run failures replay the exact
            // sequence the same spec produces in simulate_cluster.
            let faults = get("--fault-plan").map(|spec| {
                fos::sched::FaultPlan::parse(&spec).unwrap_or_else(|e| {
                    eprintln!("bad --fault-plan: {e}");
                    std::process::exit(2);
                })
            });
            let fault_spec = faults.as_ref().map(|p| p.to_spec());
            // `--scenario gen=diurnal,seed=7,tenants=4,jobs=48` (or an
            // explicit `at=...` trace) replays a recorded workload
            // through the dispatcher's virtual-time loop — the same
            // spec through simulate_cluster replays the identical
            // decision sequence.
            let scenario = get("--scenario").map(|spec| {
                fos::sched::Scenario::parse(&spec).unwrap_or_else(|e| {
                    eprintln!("bad --scenario: {e}");
                    std::process::exit(2);
                })
            });
            let scenario_spec = scenario.as_ref().map(|sc| sc.to_spec());
            // `--order seed=N` fuzzes the dispatcher's event orderings
            // (equal-time batches, ingest boundaries, tick jitter);
            // default `identity` is byte-identical to the fixed order.
            let order = get("--order")
                .map(|spec| {
                    fos::sched::OrderStrategy::parse(&spec).unwrap_or_else(|e| {
                        eprintln!("bad --order: {e}");
                        std::process::exit(2);
                    })
                })
                .unwrap_or_default();
            // `--tenants acme,bigco` switches the daemon to authenticated
            // mode: only the listed tenants (plus any registered later via
            // the admin token) can bind sessions, each with a minted
            // bearer token printed once at startup.
            let tenant_names: Vec<String> = get("--tenants")
                .map(|list| list.split(',').map(|t| t.trim().to_string()).collect())
                .unwrap_or_default();
            let tenant_refs: Vec<&str> =
                tenant_names.iter().map(String::as_str).collect();
            let mut cfg = fos::daemon::DaemonConfig::new(&boards, catalog)
                .policy(policy)
                .placement(placement)
                .admission(admission)
                .max_connections(max_conns)
                .reactor_shards(reactor_shards)
                .tenants(&tenant_refs);
            if let Some(plan) = faults {
                cfg = cfg.faults(plan);
            }
            if let Some(sc) = scenario {
                cfg = cfg.scenario(sc);
            }
            cfg = cfg.order(order);
            let d = Daemon::start_configured(&socket, cfg).expect("daemon start");
            if !tenant_names.is_empty() {
                println!(
                    "auth: admin token {}",
                    d.admin_token().expect("admin token")
                );
                for t in &tenant_names {
                    println!(
                        "auth: tenant {t:?} token {}",
                        d.tenant_token(t).expect("tenant token")
                    );
                }
            }
            let _d = d;
            let names: Vec<&str> = boards.iter().map(|b| b.name()).collect();
            println!(
                "fos daemon: boards={} placement={} policy={} socket={socket} accelerators={n} \
                 queue-cap={} max-conns={max_conns} reactor-shards={reactor_shards}{}",
                names.join(","),
                placement.name(),
                policy.name(),
                admission.queue_cap,
                fault_spec
                    .map(|sp| format!(" fault-plan={sp}"))
                    .unwrap_or_default(),
            );
            if let Some(sp) = scenario_spec {
                println!("scenario: order={} {sp}", order.to_spec());
            }
            println!("press ctrl-c to stop");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "run" => {
            let accel = get("--accel").unwrap_or_else(|| "vadd".to_string());
            let requests: usize =
                get("--requests").and_then(|s| s.parse().ok()).unwrap_or(1);
            let catalog = Catalog::load_default().expect("artifacts missing");
            let info = catalog.get(&accel).cloned().unwrap_or_else(|| {
                eprintln!("unknown accelerator {accel:?}; have: {:?}", catalog.names());
                std::process::exit(2);
            });
            let mut rpc =
                FpgaRpc::connect(&socket).expect("connect (is `fos daemon` running?)");
            // Optional QoS session: a named tenant with a DRR weight
            // and in-flight quota shared by every connection naming it.
            if let Some(tenant) = get("--tenant") {
                let weight: u32 = get("--weight").and_then(|v| v.parse().ok()).unwrap_or(1);
                let max_inflight: usize =
                    get("--max-inflight").and_then(|v| v.parse().ok()).unwrap_or(0);
                // `--token` carries the bearer token an authenticated
                // daemon (`fos daemon --tenants ...`) printed at startup.
                let token = get("--token");
                let id = rpc
                    .set_session(&tenant, token.as_deref(), weight, max_inflight)
                    .expect("session bind");
                println!("session: tenant {tenant:?} (id {id}, weight {weight})");
            }
            let mut rng = fos::testutil::Rng::new(1);
            let inputs = fos::sched::gen_inputs(&info, &mut rng);
            let mut params = Vec::new();
            for ((spec, buf), reg) in
                info.inputs.iter().zip(&inputs).zip(&info.registers[1..])
            {
                let addr = rpc.alloc(spec.bytes()).unwrap();
                rpc.write_f32(addr, buf).unwrap();
                params.push((reg.name.clone(), addr));
            }
            for (spec, reg) in info
                .outputs
                .iter()
                .zip(&info.registers[1 + info.inputs.len()..])
            {
                let addr = rpc.alloc(spec.bytes()).unwrap();
                params.push((reg.name.clone(), addr));
            }
            let jobs: Vec<Job> = (0..requests)
                .map(|_| Job::new(accel.clone(), params.clone()))
                .collect();
            let report = if args.iter().any(|a| a == "--async") {
                // Explicit ticket lifecycle: non-blocking submit, then
                // drain through the wait RPC.
                let ticket = rpc.submit(&jobs).unwrap();
                println!("submitted: ticket {ticket}");
                rpc.wait(ticket).unwrap()
            } else {
                rpc.run(&jobs).unwrap()
            };
            println!(
                "{requests} request(s) of {accel}: round-trip {:?}, daemon-side mean {:.1} us, modelled FPGA mean {:.1} us",
                report.round_trip,
                mean(&report.latencies_us),
                mean(&report.modelled_us),
            );
        }
        "info" => {
            let shell = Shell::build(board);
            let catalog = Catalog::load_default().ok();
            let t1 = shell.table1();
            let mut t = Table::new(
                format!("{} shell ({} PR regions)", shell.name, shell.region_count()),
                &["resource", "per region", "chip % (region)", "chip % (all)"],
            );
            for (k, (name, v)) in [
                ("CLB LUTs", t1.region.luts),
                ("CLB Regs", t1.region.ffs),
                ("BRAMs", t1.region.brams),
                ("DSPs", t1.region.dsps),
            ]
            .iter()
            .enumerate()
            {
                t.row(&[
                    name.to_string(),
                    v.to_string(),
                    format!("{:.2}", t1.per_region_pct[k]),
                    format!("{:.2}", t1.total_pct[k]),
                ]);
            }
            t.print();
            if let Some(c) = catalog {
                println!("\ncatalog: {} accelerators", c.accelerators.len());
                for a in &c.accelerators {
                    println!(
                        "  {:<14} [{:<6}] {}",
                        a.name,
                        a.lang,
                        a.variants
                            .iter()
                            .map(|v| format!("{} ({}R, {} cyc)", v.name, v.regions, v.cycles_per_item))
                            .collect::<Vec<_>>()
                            .join(", ")
                    );
                }
            }
        }
        "registry" => {
            let out = get("--out").unwrap_or_else(|| "registry.json".to_string());
            let shell = Shell::build(board);
            let catalog = Catalog::load_default().expect("artifacts missing");
            let reg = Registry::populate(&shell, &catalog).expect("populate");
            reg.save(&out).expect("save");
            println!("wrote {out}");
        }
        _ => {
            println!("usage: fos <daemon|run|info|registry> [flags]");
            println!("  fos daemon   [--socket PATH] [--board ultra96|ultrazed|zcu102]");
            println!("               [--boards B1,B2,...] [--placement round-robin|least-loaded|locality]");
            println!("               [--policy elastic|fixed|quantum|elastic-pre|fair]");
            println!("               [--queue-cap N] [--quantum-tiles N] [--max-conns N] [--reactor-shards N]");
            println!("               [--fault-plan seed=N,reconfig=R,run=R,down=B@Tms+Dms,...]");
            println!("               [--scenario gen=diurnal|bursts|flash|pareto,seed=N,... | v=1,at=T@tUwW:ACCELxTILES*STREAM,...]");
            println!("               [--order identity|seed=N]");
            println!("               [--tenants T1,T2,...] [--bw-partition]");
            println!("  fos run      [--socket PATH] --accel NAME [--requests N]");
            println!("               [--tenant NAME] [--token TOK] [--weight W] [--max-inflight N] [--async]");
            println!("  fos info     [--board BOARD]");
            println!("  fos registry [--board BOARD] --out FILE");
        }
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}
