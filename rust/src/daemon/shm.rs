//! Shared-memory buffers for zero-copy client↔daemon data transfer.
//!
//! The paper passes job data through shared memory so the gRPC channel
//! only carries control messages; we do the same with a file-backed
//! `mmap(MAP_SHARED)` region (put it on /dev/shm and it never touches
//! disk). Client and daemon map the same path; the RPC messages carry
//! only (path, offset, length) triples.

use std::ffi::CString;
use std::io;
use std::path::{Path, PathBuf};

/// A file-backed shared mapping.
pub struct SharedMem {
    pub path: PathBuf,
    ptr: *mut u8,
    len: usize,
    owner: bool,
}

// The mapping is plain memory; synchronisation is the user's job (the
// FOS protocol only touches a buffer from one side at a time).
unsafe impl Send for SharedMem {}

impl SharedMem {
    /// Create (or truncate) a shared region of `len` bytes at `path`.
    pub fn create(path: impl AsRef<Path>, len: usize) -> io::Result<SharedMem> {
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path.as_ref())?;
        file.set_len(len as u64)?;
        Self::map(path.as_ref().to_path_buf(), len, true)
    }

    /// Map an existing shared region.
    pub fn open(path: impl AsRef<Path>) -> io::Result<SharedMem> {
        let len = std::fs::metadata(path.as_ref())?.len() as usize;
        Self::map(path.as_ref().to_path_buf(), len, false)
    }

    fn map(path: PathBuf, len: usize, owner: bool) -> io::Result<SharedMem> {
        let cpath = CString::new(path.as_os_str().as_encoded_bytes())
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "nul in path"))?;
        unsafe {
            let fd = libc::open(cpath.as_ptr(), libc::O_RDWR);
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            let ptr = libc::mmap(
                std::ptr::null_mut(),
                len.max(1),
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            libc::close(fd);
            if ptr == libc::MAP_FAILED {
                return Err(io::Error::last_os_error());
            }
            Ok(SharedMem { path, ptr: ptr as *mut u8, len, owner })
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    pub fn write_f32(&mut self, offset: usize, data: &[f32]) -> io::Result<()> {
        let end = offset + data.len() * 4;
        if end > self.len {
            return Err(io::Error::new(io::ErrorKind::InvalidInput, "shm overflow"));
        }
        let s = self.as_mut_slice();
        for (k, v) in data.iter().enumerate() {
            s[offset + 4 * k..offset + 4 * k + 4].copy_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    pub fn read_f32(&self, offset: usize, count: usize) -> io::Result<Vec<f32>> {
        let end = offset + count * 4;
        if end > self.len {
            return Err(io::Error::new(io::ErrorKind::InvalidInput, "shm overread"));
        }
        let s = self.as_slice();
        Ok((0..count)
            .map(|k| f32::from_le_bytes(s[offset + 4 * k..offset + 4 * k + 4].try_into().unwrap()))
            .collect())
    }
}

impl Drop for SharedMem {
    fn drop(&mut self) {
        unsafe {
            libc::munmap(self.ptr as *mut libc::c_void, self.len.max(1));
        }
        if self.owner {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = if Path::new("/dev/shm").is_dir() { "/dev/shm" } else { "/tmp" };
        Path::new(dir).join(format!("fos_shm_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn create_write_open_read() {
        let path = tmp("rw");
        let mut a = SharedMem::create(&path, 4096).unwrap();
        a.write_f32(0, &[1.0, 2.5, -3.0]).unwrap();
        a.write_f32(4080, &[9.0]).unwrap();
        // Another mapping of the same file sees the data (zero copy).
        let b = SharedMem::open(&path).unwrap();
        assert_eq!(b.read_f32(0, 3).unwrap(), vec![1.0, 2.5, -3.0]);
        assert_eq!(b.read_f32(4080, 1).unwrap(), vec![9.0]);
        drop(b);
        drop(a); // owner unlinks
        assert!(!path.exists());
    }

    #[test]
    fn bounds_checked() {
        let path = tmp("bounds");
        let mut m = SharedMem::create(&path, 16).unwrap();
        assert!(m.write_f32(8, &[1.0, 2.0, 3.0]).is_err());
        assert!(m.read_f32(12, 2).is_err());
        m.write_f32(12, &[4.0]).unwrap();
    }

    #[test]
    fn cross_mapping_mutation_visible() {
        let path = tmp("mut");
        let mut a = SharedMem::create(&path, 64).unwrap();
        let mut b = SharedMem::open(&path).unwrap();
        a.write_f32(0, &[7.0]).unwrap();
        assert_eq!(b.read_f32(0, 1).unwrap(), vec![7.0]);
        b.write_f32(0, &[8.0]).unwrap();
        assert_eq!(a.read_f32(0, 1).unwrap(), vec![8.0]);
    }
}
