//! The daemon process: UDS accept loop + single dispatcher thread that
//! owns the FPGA (Cynq stack) and round-robins requests across users.

use super::proto::{self, read_msg, write_msg, Job};
use super::shm::SharedMem;
use crate::accel::Catalog;
use crate::driver::{Cynq, LoadedAccel, PhysAddr};
use crate::json::{arr, f, i, obj, s, Value};
use crate::shell::ShellBoard;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Daemon-side counters (Table 4/5 material).
#[derive(Debug, Default)]
pub struct DaemonStats {
    pub jobs: AtomicU64,
    pub reconfig_loads: AtomicU64,
    pub reuse_hits: AtomicU64,
    /// Scheduling decision time (pick user/region/variant), ns.
    pub sched_ns: AtomicU64,
    pub sched_decisions: AtomicU64,
    pub rpcs: AtomicU64,
}

enum Msg {
    Submit {
        user: u64,
        jobs: Vec<Job>,
        reply: mpsc::Sender<Value>,
    },
    Mem {
        op: MemOp,
        reply: mpsc::Sender<Value>,
    },
    Stop,
}

enum MemOp {
    Alloc { bytes: usize },
    Free { addr: u64 },
    Write { addr: u64, data: Vec<f32> },
    Read { addr: u64, count: usize },
    Import { shm: PathBuf, offset: usize, count: usize, addr: u64 },
    Export { addr: u64, count: usize, shm: PathBuf, offset: usize },
}

/// A running daemon instance.
pub struct Daemon {
    pub socket_path: PathBuf,
    stats: Arc<DaemonStats>,
    tx: mpsc::Sender<Msg>,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    dispatch_handle: Option<std::thread::JoinHandle<()>>,
}

impl Daemon {
    /// Start the daemon: bind the socket, bring up the FPGA, spawn the
    /// accept loop and the dispatcher.
    pub fn start(
        socket_path: impl AsRef<Path>,
        board: ShellBoard,
        catalog: Catalog,
    ) -> io::Result<Daemon> {
        let socket_path = socket_path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&socket_path);
        let listener = UnixListener::bind(&socket_path)?;
        listener.set_nonblocking(true)?;
        let cynq = Cynq::open(board, catalog)
            .map_err(|e| io::Error::new(io::ErrorKind::Other, e.to_string()))?;

        let stats = Arc::new(DaemonStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Msg>();

        let dispatch_handle = {
            let stats = stats.clone();
            std::thread::Builder::new()
                .name("fos-dispatch".into())
                .spawn(move || dispatcher(cynq, rx, stats))?
        };

        let accept_handle = {
            let tx = tx.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            std::thread::Builder::new().name("fos-accept".into()).spawn(move || {
                let mut next_user = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let user = next_user;
                            next_user += 1;
                            let tx = tx.clone();
                            let stats = stats.clone();
                            std::thread::spawn(move || {
                                let _ = connection(stream, user, tx, stats);
                            });
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?
        };

        Ok(Daemon {
            socket_path,
            stats,
            tx,
            stop,
            accept_handle: Some(accept_handle),
            dispatch_handle: Some(dispatch_handle),
        })
    }

    pub fn stats(&self) -> &DaemonStats {
        &self.stats
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.dispatch_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.socket_path);
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-connection request loop.
fn connection(
    mut stream: UnixStream,
    user: u64,
    tx: mpsc::Sender<Msg>,
    stats: Arc<DaemonStats>,
) -> Result<(), proto::ProtoError> {
    loop {
        let msg = match read_msg(&mut stream) {
            Ok(m) => m,
            Err(_) => return Ok(()), // client hung up
        };
        stats.rpcs.fetch_add(1, Ordering::Relaxed);
        let method = msg.get("method").as_str().unwrap_or("");
        let resp = match method {
            "ping" => ok(vec![("user", i(user as i64))]),
            "run" => {
                let jobs: Result<Vec<Job>, _> = msg
                    .req_array("jobs")
                    .map_err(proto::ProtoError::Schema)?
                    .iter()
                    .map(Job::from_value)
                    .collect();
                match jobs {
                    Err(e) => err_val(&e.to_string()),
                    Ok(jobs) => {
                        let (rtx, rrx) = mpsc::channel();
                        if tx.send(Msg::Submit { user, jobs, reply: rtx }).is_err() {
                            err_val("daemon stopping")
                        } else {
                            rrx.recv().unwrap_or_else(|_| err_val("dispatcher died"))
                        }
                    }
                }
            }
            "alloc" | "free" | "write" | "read" | "import" | "export" => {
                match parse_mem_op(method, &msg) {
                    Err(e) => err_val(&e),
                    Ok(op) => {
                        let (rtx, rrx) = mpsc::channel();
                        if tx.send(Msg::Mem { op, reply: rtx }).is_err() {
                            err_val("daemon stopping")
                        } else {
                            rrx.recv().unwrap_or_else(|_| err_val("dispatcher died"))
                        }
                    }
                }
            }
            other => err_val(&format!("unknown method {other:?}")),
        };
        write_msg(&mut stream, &resp)?;
    }
}

fn parse_mem_op(method: &str, msg: &Value) -> Result<MemOp, String> {
    Ok(match method {
        "alloc" => MemOp::Alloc { bytes: msg.req_u64("bytes")? as usize },
        "free" => MemOp::Free { addr: msg.req_u64("addr")? },
        "write" => MemOp::Write {
            addr: msg.req_u64("addr")?,
            data: proto::b64_to_f32s(msg.req_str("b64")?).map_err(|e| e.to_string())?,
        },
        "read" => MemOp::Read {
            addr: msg.req_u64("addr")?,
            count: msg.req_u64("count")? as usize,
        },
        "import" => MemOp::Import {
            shm: msg.req_str("shm")?.into(),
            offset: msg.req_u64("offset")? as usize,
            count: msg.req_u64("count")? as usize,
            addr: msg.req_u64("addr")?,
        },
        "export" => MemOp::Export {
            addr: msg.req_u64("addr")?,
            count: msg.req_u64("count")? as usize,
            shm: msg.req_str("shm")?.into(),
            offset: msg.req_u64("offset")? as usize,
        },
        _ => unreachable!(),
    })
}

/// The dispatcher: owns the FPGA; round-robin across user queues at
/// acceleration-request granularity (§4.4.3).
fn dispatcher(mut cynq: Cynq, rx: mpsc::Receiver<Msg>, stats: Arc<DaemonStats>) {
    struct Batch {
        reply: mpsc::Sender<Value>,
        remaining: usize,
        latencies_us: Vec<f64>,
        modelled_us: Vec<f64>,
        error: Option<String>,
    }
    let mut queues: BTreeMap<u64, VecDeque<(Job, usize)>> = BTreeMap::new();
    let mut batches: Vec<Batch> = Vec::new();
    let mut loaded: HashMap<String, LoadedAccel> = HashMap::new();
    let mut lru: Vec<String> = Vec::new();
    let mut rr_last: Option<u64> = None;

    'outer: loop {
        // Block when idle; drain without blocking when busy.
        let msg = if queues.values().all(|q| q.is_empty()) {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            }
        } else {
            rx.try_recv().ok()
        };
        if let Some(msg) = msg {
            match msg {
                Msg::Stop => break 'outer,
                Msg::Mem { op, reply } => {
                    let _ = reply.send(mem_op(&mut cynq, op));
                }
                Msg::Submit { user, jobs, reply } => {
                    let idx = batches.len();
                    batches.push(Batch {
                        reply,
                        remaining: jobs.len(),
                        latencies_us: Vec::new(),
                        modelled_us: Vec::new(),
                        error: None,
                    });
                    if jobs.is_empty() {
                        finish(&mut batches[idx]);
                        continue;
                    }
                    let q = queues.entry(user).or_default();
                    for j in jobs {
                        q.push_back((j, idx));
                    }
                }
            }
            continue; // re-check for more messages before dispatching
        }

        // Dispatch ONE request (cooperative run-to-completion), from the
        // next user after the last-served one (round-robin).
        let users: Vec<u64> = queues.keys().copied().collect();
        if users.is_empty() {
            continue;
        }
        let start_pos = rr_last
            .and_then(|last| users.iter().position(|&u| u == last).map(|p| p + 1))
            .unwrap_or(0);
        let Some(&user) = (0..users.len())
            .map(|k| &users[(start_pos + k) % users.len()])
            .find(|&&u| !queues[&u].is_empty())
        else {
            continue;
        };
        rr_last = Some(user);
        let (job, batch_idx) = queues.get_mut(&user).unwrap().pop_front().unwrap();

        // Scheduling decision: reuse a loaded accelerator or decide to
        // load one (evicting idle LRU modules if the fabric is full).
        // Only the *decision* is scheduler latency (Table 4); the
        // bitstream generation + PCAP load that follows is
        // reconfiguration latency, accounted separately (Table 5).
        let t_sched = Instant::now();
        let decision = match loaded.get(&job.accname) {
            Some(&h) => {
                stats.reuse_hits.fetch_add(1, Ordering::Relaxed);
                touch(&mut lru, &job.accname);
                Some(h)
            }
            None => {
                while cynq.free_regions() == 0 && !lru.is_empty() {
                    let victim = lru.remove(0);
                    if let Some(h) = loaded.remove(&victim) {
                        let _ = cynq.unload(h);
                    }
                }
                None
            }
        };
        stats
            .sched_ns
            .fetch_add(t_sched.elapsed().as_nanos() as u64, Ordering::Relaxed);
        stats.sched_decisions.fetch_add(1, Ordering::Relaxed);

        let handle = match decision {
            Some(h) => Ok(h),
            None => match cynq.load_accelerator(&job.accname, None) {
                Ok((h, _)) => {
                    stats.reconfig_loads.fetch_add(1, Ordering::Relaxed);
                    loaded.insert(job.accname.clone(), h);
                    touch(&mut lru, &job.accname);
                    Ok(h)
                }
                Err(e) => Err(e.to_string()),
            },
        };

        let t0 = Instant::now();
        let outcome = handle.and_then(|h| {
            for (reg, val) in &job.params {
                cynq.write_reg(h, reg, PhysAddr(*val)).map_err(|e| e.to_string())?;
            }
            cynq.run(h).map_err(|e| e.to_string())
        });
        stats.jobs.fetch_add(1, Ordering::Relaxed);

        let b = &mut batches[batch_idx];
        match outcome {
            Ok(modelled) => {
                b.latencies_us.push(t0.elapsed().as_secs_f64() * 1e6);
                b.modelled_us.push(modelled.as_secs_f64() * 1e6);
            }
            Err(e) => b.error = Some(e),
        }
        b.remaining -= 1;
        if b.remaining == 0 {
            finish(b);
        }
    }

    fn finish(b: &mut Batch) {
        let resp = match &b.error {
            Some(e) => err_val(e),
            None => ok(vec![
                (
                    "latencies_us",
                    arr(b.latencies_us.iter().map(|&x| f(x)).collect()),
                ),
                (
                    "modelled_us",
                    arr(b.modelled_us.iter().map(|&x| f(x)).collect()),
                ),
            ]),
        };
        let _ = b.reply.send(resp);
    }
}

fn touch(lru: &mut Vec<String>, name: &str) {
    lru.retain(|n| n != name);
    lru.push(name.to_string());
}

fn mem_op(cynq: &mut Cynq, op: MemOp) -> Value {
    match op {
        MemOp::Alloc { bytes } => match cynq.alloc(bytes) {
            Ok(a) => ok(vec![("addr", i(a.0 as i64))]),
            Err(e) => err_val(&e.to_string()),
        },
        MemOp::Free { addr } => match cynq.mem.free(PhysAddr(addr)) {
            Ok(()) => ok(vec![]),
            Err(e) => err_val(&e.to_string()),
        },
        MemOp::Write { addr, data } => match cynq.write_f32(PhysAddr(addr), &data) {
            Ok(()) => ok(vec![]),
            Err(e) => err_val(&e.to_string()),
        },
        MemOp::Read { addr, count } => match cynq.read_f32(PhysAddr(addr), count) {
            Ok(data) => ok(vec![("b64", s(proto::f32s_to_b64(&data)))]),
            Err(e) => err_val(&e.to_string()),
        },
        MemOp::Import { shm, offset, count, addr } => {
            match SharedMem::open(&shm)
                .map_err(|e| e.to_string())
                .and_then(|m| m.read_f32(offset, count).map_err(|e| e.to_string()))
                .and_then(|data| {
                    cynq.write_f32(PhysAddr(addr), &data).map_err(|e| e.to_string())
                }) {
                Ok(()) => ok(vec![]),
                Err(e) => err_val(&e),
            }
        }
        MemOp::Export { addr, count, shm, offset } => {
            match cynq
                .read_f32(PhysAddr(addr), count)
                .map_err(|e| e.to_string())
                .and_then(|data| {
                    SharedMem::open(&shm)
                        .map_err(|e| e.to_string())
                        .and_then(|mut m| m.write_f32(offset, &data).map_err(|e| e.to_string()))
                }) {
                Ok(()) => ok(vec![]),
                Err(e) => err_val(&e),
            }
        }
    }
}

fn ok(mut fields: Vec<(&str, Value)>) -> Value {
    fields.insert(0, ("status", s("ok")));
    obj(fields)
}

fn err_val(e: &str) -> Value {
    obj(vec![("status", s("err")), ("error", s(e))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::daemon::FpgaRpc;
    use once_cell::sync::Lazy;
    use std::sync::Mutex;

    static LOCK: Lazy<Mutex<()>> = Lazy::new(|| Mutex::new(()));

    fn sock(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("fos_daemon_{name}_{}.sock", std::process::id()))
    }

    fn start(name: &str) -> (Daemon, PathBuf) {
        let path = sock(name);
        let d = Daemon::start(&path, ShellBoard::Ultra96, Catalog::load_default().unwrap())
            .unwrap();
        (d, path)
    }

    #[test]
    fn single_client_vadd_end_to_end() {
        let _g = LOCK.lock().unwrap();
        let (_d, path) = start("vadd");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let a = rpc.alloc(4 * 4096).unwrap();
        let b = rpc.alloc(4 * 4096).unwrap();
        let c = rpc.alloc(4 * 4096).unwrap();
        let xs: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let ys: Vec<f32> = (0..4096).map(|i| (i * 2) as f32).collect();
        rpc.write_f32(a, &xs).unwrap();
        rpc.write_f32(b, &ys).unwrap();
        let job = Job {
            accname: "vadd".into(),
            params: vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
        };
        let report = rpc.run(&[job]).unwrap();
        assert_eq!(report.latencies_us.len(), 1);
        assert!(report.modelled_us[0] > 0.0);
        let out = rpc.read_f32(c, 4096).unwrap();
        for k in 0..4096 {
            assert_eq!(out[k], (k * 3) as f32);
        }
    }

    #[test]
    fn two_tenants_interleave_and_share() {
        let _g = LOCK.lock().unwrap();
        let (d, path) = start("multi");
        let mk = |rpc: &mut FpgaRpc, n: usize| -> (u64, u64, u64, Vec<Job>) {
            let a = rpc.alloc(4 * 4096).unwrap();
            let b = rpc.alloc(4 * 4096).unwrap();
            let c = rpc.alloc(4 * 4096).unwrap();
            rpc.write_f32(a, &vec![1.0; 4096]).unwrap();
            rpc.write_f32(b, &vec![2.0; 4096]).unwrap();
            let jobs = (0..n)
                .map(|_| Job {
                    accname: "vadd".into(),
                    params: vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
                })
                .collect();
            (a, b, c, jobs)
        };
        let path2 = path.clone();
        let t1 = std::thread::spawn(move || {
            let mut rpc = FpgaRpc::connect(&path2).unwrap();
            let (_, _, c, jobs) = mk(&mut rpc, 4);
            rpc.run(&jobs).unwrap();
            rpc.read_f32(c, 4096).unwrap()
        });
        let path3 = path.clone();
        let t2 = std::thread::spawn(move || {
            let mut rpc = FpgaRpc::connect(&path3).unwrap();
            let (_, _, c, jobs) = mk(&mut rpc, 4);
            rpc.run(&jobs).unwrap();
            rpc.read_f32(c, 4096).unwrap()
        });
        let o1 = t1.join().unwrap();
        let o2 = t2.join().unwrap();
        assert!(o1.iter().all(|&v| v == 3.0));
        assert!(o2.iter().all(|&v| v == 3.0));
        // Both users ran the same accelerator: reuse must have happened.
        assert!(d.stats().reuse_hits.load(Ordering::Relaxed) >= 6);
        assert_eq!(d.stats().jobs.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn shm_zero_copy_path() {
        let _g = LOCK.lock().unwrap();
        let (_d, path) = start("shm");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let shm_path = std::env::temp_dir().join(format!("fos_shm_{}.bin", std::process::id()));
        let mut shm = SharedMem::create(&shm_path, 4 * 4096 * 2).unwrap();
        let xs: Vec<f32> = (0..4096).map(|i| (i % 97) as f32).collect();
        shm.write_f32(0, &xs).unwrap();
        let a = rpc.alloc(4 * 4096).unwrap();
        let o = rpc.alloc(4 * 4096).unwrap();
        rpc.import_shm(&shm.path, 0, 4096, a).unwrap();
        let job = Job {
            accname: "aes".into(),
            params: vec![("in_data".into(), a), ("out_data".into(), o)],
        };
        rpc.run(&[job]).unwrap();
        rpc.export_shm(o, 4096, &shm.path, 4 * 4096).unwrap();
        let out = shm.read_f32(4 * 4096, 4096).unwrap();
        // ARX cipher is a bijection: output differs from input everywhere
        // except possibly a few fixed points; check it's not identity.
        let same = out.iter().zip(&xs).filter(|(a, b)| a == b).count();
        assert!(same < 100, "{same} unchanged values");
    }

    #[test]
    fn unknown_accelerator_reports_error() {
        let _g = LOCK.lock().unwrap();
        let (_d, path) = start("err");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let job = Job { accname: "flux_capacitor".into(), params: vec![] };
        assert!(matches!(rpc.run(&[job]), Err(proto::ProtoError::Remote(_))));
        // Connection still usable after an error.
        assert!(rpc.ping().is_ok());
    }
}
