//! The daemon process: UDS accept loop + single dispatcher thread that
//! owns the FPGA (Cynq stack) and schedules requests across users
//! through the shared resource-elastic scheduler core
//! ([`crate::sched::SchedCore`]) — the same state machine the offline
//! simulator drives, so the live path gains variant selection,
//! multi-region spans, replication across free regions and
//! backlog-amortised reconfiguration avoidance (§4.4.3).
//!
//! The dispatcher keeps a *virtual clock*: each decision's service time
//! comes from the shared [`crate::sched::CostModel`] and completions
//! are replayed into the core in virtual-time order, exactly like the
//! simulator's event heap.  Reconfigurations are mirrored onto the
//! hardware at decision time; register programming + PJRT compute are
//! deferred to the decision's virtual completion, so a `Preempt`
//! decision can split a batch exactly where the virtual clock says —
//! the completed slice runs and is checkpointed
//! (`Cynq::checkpoint_accelerator`), the remainder resumes later
//! (`Cynq::restore_accelerator`), and no tile is computed twice.  For
//! one trace the simulator and the daemon produce identical decision
//! sequences — preemptions included — asserted by
//! `tests/sched_parity.rs`.

use super::proto::{self, read_msg, write_msg, Job};
use super::shm::SharedMem;
use crate::accel::Catalog;
use crate::driver::{AccelSnapshot, Cynq, LoadedAccel, PhysAddr};
use crate::json::{arr, f, i, obj, s, Value};
use crate::sched::{Decision, DecisionKind, Policy, SchedCore, SchedCounters};
use crate::shell::ShellBoard;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::io;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Daemon-side counters (Table 4/5 material). The scheduling counters
/// (`reconfig_loads`, `reuse_hits`, `skips`, `replications`) mirror the
/// core's [`crate::sched::SchedCounters`] — one source of truth for
/// both the simulator and the daemon.
#[derive(Debug, Default)]
pub struct DaemonStats {
    pub jobs: AtomicU64,
    pub reconfig_loads: AtomicU64,
    pub reuse_hits: AtomicU64,
    /// Rounds where a user was deferred (reconfiguration avoidance,
    /// busy fixed home).
    pub skips: AtomicU64,
    /// Reconfigurations that created an additional instance of an
    /// already-resident accelerator.
    pub replications: AtomicU64,
    /// Running requests checkpointed and requeued (time-domain
    /// preemption; mirrors `SchedCounters::preemptions`).
    pub preemptions: AtomicU64,
    /// Requeued remainders re-dispatched (mirrors
    /// `SchedCounters::resumes`).
    pub resumes: AtomicU64,
    /// Jobs served while ≥2 instances of their accelerator were
    /// resident (served by a replicated instance).
    pub replicated_jobs: AtomicU64,
    /// Scheduling decision time (pick user/region/variant), ns.
    pub sched_ns: AtomicU64,
    pub sched_decisions: AtomicU64,
    pub rpcs: AtomicU64,
}

enum Msg {
    /// A connection opened (sent by its first `ping`): bind the daemon
    /// user id to a recycled scheduler slot.
    Hello {
        user: u64,
        reply: mpsc::Sender<Value>,
    },
    /// A connection closed: retire its scheduler slot for reuse.
    Goodbye {
        user: u64,
    },
    Submit {
        user: u64,
        jobs: Vec<Job>,
        reply: mpsc::Sender<Value>,
    },
    Mem {
        op: MemOp,
        reply: mpsc::Sender<Value>,
    },
    SetPolicy {
        user: u64,
        name: String,
        reply: mpsc::Sender<Value>,
    },
    Pause {
        reply: mpsc::Sender<Value>,
    },
    Resume {
        reply: mpsc::Sender<Value>,
    },
    Query {
        reply: mpsc::Sender<Value>,
    },
    /// Snapshot of the scheduler core's ordered decision log — the
    /// last `limit` entries, or all retained ones when `None`.
    QueryLog {
        limit: Option<usize>,
        reply: mpsc::Sender<Vec<Decision>>,
    },
    Stop,
}

enum MemOp {
    Alloc { bytes: usize },
    Free { addr: u64 },
    Write { addr: u64, data: Vec<f32> },
    Read { addr: u64, count: usize },
    Import { shm: PathBuf, offset: usize, count: usize, addr: u64 },
    Export { addr: u64, count: usize, shm: PathBuf, offset: usize },
}

/// A running daemon instance.
pub struct Daemon {
    pub socket_path: PathBuf,
    stats: Arc<DaemonStats>,
    tx: mpsc::Sender<Msg>,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    dispatch_handle: Option<std::thread::JoinHandle<()>>,
}

impl Daemon {
    /// Start the daemon under the resource-elastic default policy.
    pub fn start(
        socket_path: impl AsRef<Path>,
        board: ShellBoard,
        catalog: Catalog,
    ) -> io::Result<Daemon> {
        Self::start_with_policy(socket_path, board, catalog, Policy::Elastic)
    }

    /// Start the daemon: bind the socket, bring up the FPGA, spawn the
    /// accept loop and the dispatcher. `default_policy` routes tenants
    /// that never call `FpgaRpc::set_policy`.
    pub fn start_with_policy(
        socket_path: impl AsRef<Path>,
        board: ShellBoard,
        catalog: Catalog,
        default_policy: Policy,
    ) -> io::Result<Daemon> {
        let socket_path = socket_path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&socket_path);
        let listener = UnixListener::bind(&socket_path)?;
        listener.set_nonblocking(true)?;
        let cynq = Cynq::open(board, catalog)
            .map_err(|e| io::Error::new(io::ErrorKind::Other, e.to_string()))?;

        let stats = Arc::new(DaemonStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Msg>();

        let dispatch_handle = {
            let stats = stats.clone();
            std::thread::Builder::new()
                .name("fos-dispatch".into())
                .spawn(move || dispatcher(cynq, rx, stats, default_policy))?
        };

        let accept_handle = {
            let tx = tx.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            std::thread::Builder::new().name("fos-accept".into()).spawn(move || {
                let mut next_user = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let user = next_user;
                            next_user += 1;
                            let tx = tx.clone();
                            let stats = stats.clone();
                            std::thread::spawn(move || {
                                let _ = connection(stream, user, tx, stats);
                            });
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?
        };

        Ok(Daemon {
            socket_path,
            stats,
            tx,
            stop,
            accept_handle: Some(accept_handle),
            dispatch_handle: Some(dispatch_handle),
        })
    }

    pub fn stats(&self) -> &DaemonStats {
        &self.stats
    }

    /// Snapshot of the scheduler core's ordered decision log (the most
    /// recent entries, ring-capped by the core). Empty once the
    /// dispatcher has stopped.
    pub fn decision_log(&self) -> Vec<Decision> {
        self.decision_log_query(None)
    }

    /// The last `n` decisions only — what monitoring loops should poll
    /// (a full-log snapshot clones up to the whole ring).
    pub fn decision_log_tail(&self, n: usize) -> Vec<Decision> {
        self.decision_log_query(Some(n))
    }

    fn decision_log_query(&self, limit: Option<usize>) -> Vec<Decision> {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(Msg::QueryLog { limit, reply: rtx }).is_err() {
            return Vec::new();
        }
        rrx.recv().unwrap_or_default()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.dispatch_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.socket_path);
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Request/reply round-trip with the dispatcher thread.
fn ask(tx: &mpsc::Sender<Msg>, make: impl FnOnce(mpsc::Sender<Value>) -> Msg) -> Value {
    let (rtx, rrx) = mpsc::channel();
    if tx.send(make(rtx)).is_err() {
        return err_val("daemon stopping");
    }
    rrx.recv().unwrap_or_else(|_| err_val("dispatcher died"))
}

/// Per-connection request loop (retires the user's scheduler slot on
/// exit, however the connection ends).
fn connection(
    mut stream: UnixStream,
    user: u64,
    tx: mpsc::Sender<Msg>,
    stats: Arc<DaemonStats>,
) -> Result<(), proto::ProtoError> {
    let r = serve(&mut stream, user, &tx, &stats);
    let _ = tx.send(Msg::Goodbye { user });
    r
}

fn serve(
    stream: &mut UnixStream,
    user: u64,
    tx: &mpsc::Sender<Msg>,
    stats: &Arc<DaemonStats>,
) -> Result<(), proto::ProtoError> {
    loop {
        let msg = match read_msg(stream) {
            Ok(m) => m,
            Err(_) => return Ok(()), // client hung up
        };
        stats.rpcs.fetch_add(1, Ordering::Relaxed);
        let method = msg.get("method").as_str().unwrap_or("");
        let resp = match method {
            "ping" => ask(tx, |reply| Msg::Hello { user, reply }),
            "run" => {
                let jobs: Result<Vec<Job>, _> = msg
                    .req_array("jobs")
                    .map_err(proto::ProtoError::Schema)?
                    .iter()
                    .map(Job::from_value)
                    .collect();
                match jobs {
                    Err(e) => err_val(&e.to_string()),
                    Ok(jobs) => ask(tx, |reply| Msg::Submit { user, jobs, reply }),
                }
            }
            "policy" => match msg.req_str("policy") {
                Err(e) => err_val(&e),
                Ok(name) => {
                    let name = name.to_string();
                    ask(tx, |reply| Msg::SetPolicy { user, name, reply })
                }
            },
            "pause" => ask(tx, |reply| Msg::Pause { reply }),
            "resume" => ask(tx, |reply| Msg::Resume { reply }),
            "stats" => ask(tx, |reply| Msg::Query { reply }),
            "alloc" | "free" | "write" | "read" | "import" | "export" => {
                match parse_mem_op(method, &msg) {
                    Err(e) => err_val(&e),
                    Ok(op) => ask(tx, |reply| Msg::Mem { op, reply }),
                }
            }
            other => err_val(&format!("unknown method {other:?}")),
        };
        write_msg(stream, &resp)?;
    }
}

fn parse_mem_op(method: &str, msg: &Value) -> Result<MemOp, String> {
    Ok(match method {
        "alloc" => MemOp::Alloc { bytes: msg.req_u64("bytes")? as usize },
        "free" => MemOp::Free { addr: msg.req_u64("addr")? },
        "write" => MemOp::Write {
            addr: msg.req_u64("addr")?,
            data: proto::b64_to_f32s(msg.req_str("b64")?).map_err(|e| e.to_string())?,
        },
        "read" => MemOp::Read {
            addr: msg.req_u64("addr")?,
            count: msg.req_u64("count")? as usize,
        },
        "import" => MemOp::Import {
            shm: msg.req_str("shm")?.into(),
            offset: msg.req_u64("offset")? as usize,
            count: msg.req_u64("count")? as usize,
            addr: msg.req_u64("addr")?,
        },
        "export" => MemOp::Export {
            addr: msg.req_u64("addr")?,
            count: msg.req_u64("count")? as usize,
            shm: msg.req_str("shm")?.into(),
            offset: msg.req_u64("offset")? as usize,
        },
        _ => unreachable!(),
    })
}

struct Batch {
    reply: mpsc::Sender<Value>,
    remaining: usize,
    latencies_us: Vec<f64>,
    modelled_us: Vec<f64>,
    error: Option<String>,
}

fn finish(b: Batch) {
    let resp = match &b.error {
        Some(e) => err_val(e),
        None => ok(vec![
            (
                "latencies_us",
                arr(b.latencies_us.iter().map(|&x| f(x)).collect()),
            ),
            (
                "modelled_us",
                arr(b.modelled_us.iter().map(|&x| f(x)).collect()),
            ),
        ]),
    };
    let _ = b.reply.send(resp);
}

/// A submitted proto job awaiting its (next) scheduling decision.  A
/// preempted job re-enters this map carrying the real/modelled time its
/// completed slices already consumed, plus any failure to report once
/// its remainder finally completes.
struct PendingJob {
    job: Job,
    batch: usize,
    /// Real execution µs accumulated by earlier preempted slices.
    carry_us: f64,
    /// Modelled virtual µs consumed by earlier preempted slices.
    carry_modelled_us: f64,
    /// A slice already failed; report at the final completion.
    failed: Option<String>,
}

impl PendingJob {
    fn new(job: Job, batch: usize) -> PendingJob {
        PendingJob { job, batch, carry_us: 0.0, carry_modelled_us: 0.0, failed: None }
    }
}

/// A dispatched decision whose execution is deferred to its virtual
/// completion — or to an earlier preemption of its anchor, which runs
/// only the completed slice and checkpoints the rest.  Deferral is what
/// lets the daemon split work *exactly* where the core's `Preempt`
/// decision says, instead of having eagerly computed the whole batch.
struct Inflight {
    d: Decision,
    job: Job,
    batch: usize,
    /// Module handle for execution; `None` when the (re)load failed —
    /// `err` below then surfaces at completion.
    handle: Option<LoadedAccel>,
    err: Option<String>,
    /// Virtual dispatch time and modelled service time.
    start_ns: u64,
    lat_ns: u64,
    carry_us: f64,
    carry_modelled_us: f64,
}

/// Sentinel "anchor" for preemption-check tick entries in the
/// completion heap: never registered in `inflight`, so popping one only
/// advances the virtual clock and triggers a round — exactly the
/// simulator's `Event::Tick`.
const TICK_ANCHOR: usize = usize::MAX;

/// Fail one admitted-but-unfinished job of a batch, sending the batch
/// reply when it was the last outstanding unit — the single bookkeeping
/// path shared by client disconnects and the stall guard.
fn fail_job(batches: &mut HashMap<usize, Batch>, batch_id: usize, err: String) {
    if let Some(b) = batches.get_mut(&batch_id) {
        b.error = Some(err);
        b.remaining -= 1;
        if b.remaining == 0 {
            let b = batches.remove(&batch_id).unwrap();
            finish(b);
        }
    }
}

/// The dispatcher: owns the FPGA and drives the shared scheduler core.
/// Blocks on the channel when idle or paused; while work is in flight
/// it alternates message draining, scheduling rounds and virtual-time
/// completion replay — never a hot spin.
///
/// Execution is *deferred*: a decision mirrors its reconfiguration onto
/// the hardware immediately (that is when the fabric changes), but
/// register programming and tile compute run when the decision's
/// virtual completion is replayed.  A `Preempt` decision arriving
/// before that point cancels the completion, runs only the tiles the
/// virtual clock says finished, and checkpoints the accelerator —
/// so preempted work is split, never recomputed.
fn dispatcher(mut cynq: Cynq, rx: mpsc::Receiver<Msg>, stats: Arc<DaemonStats>, policy: Policy) {
    let mut core = SchedCore::new(&cynq.shell, cynq.catalog.clone(), policy);
    // Live batches only — finished ones are removed, so a long-lived
    // daemon does not accumulate per-job state.
    let mut batches: HashMap<usize, Batch> = HashMap::new();
    let mut next_batch = 0usize;
    let mut pending: HashMap<u64, PendingJob> = HashMap::new();
    let mut next_token = 0u64;
    // Daemon connection id -> scheduler slot; slots are recycled on
    // Goodbye so core state is bounded by peak concurrent tenants.
    let mut user_index: HashMap<u64, usize> = HashMap::new();
    let mut free_slots: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
    let mut next_fresh = 0usize;
    // State-changing messages deferred from mid-round draining (see
    // the round loop): processed before new channel messages.
    let mut inbox: VecDeque<Msg> = VecDeque::new();
    // anchor -> (handle, span) of the modules on the fabric.
    let mut resident: HashMap<usize, (LoadedAccel, usize)> = HashMap::new();
    // (virtual completion time, seq, anchor) — the simulator's heap.
    let mut completions: BinaryHeap<Reverse<(u64, u64, usize)>> = BinaryHeap::new();
    // seq -> deferred execution context of a dispatched decision.  An
    // entry missing at completion-pop means the dispatch was preempted
    // (or the entry is a tick): the pop only advances virtual time.
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    // anchor -> seq of the dispatch currently running there.
    let mut running_seq: HashMap<usize, u64> = HashMap::new();
    // checkpoint id -> register-file + progress snapshot (the hardware
    // half of the core's checkpoint store).
    let mut snapshots: HashMap<u64, AccelSnapshot> = HashMap::new();
    // One pending preemption-check tick at a time (sim parity).
    let mut next_tick: Option<u64> = None;
    let mut seq = 0u64;
    let mut vnow = 0u64;
    let mut paused = false;
    // A scheduling round is due: new admissions, a policy change or a
    // virtual-time advance happened since the last one. Mirrors the
    // simulator's one-round-per-event-batch cadence, which keeps the
    // decision (and skip-counter) sequences identical on both paths.
    let mut round_due = false;

    'outer: loop {
        // Block when idle or paused (no busy-spin); drain without
        // blocking while a round is due or completions are in flight.
        let idle = paused || (!round_due && completions.is_empty());
        let msg = match inbox.pop_front() {
            Some(m) => Some(m),
            None if idle => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break 'outer,
            },
            None => rx.try_recv().ok(),
        };
        if let Some(msg) = msg {
            let Some(msg) = handle_cheap(
                msg,
                &mut cynq,
                &core,
                &mut paused,
                &mut user_index,
                &mut free_slots,
                &mut next_fresh,
            ) else {
                continue;
            };
            match msg {
                Msg::Stop => break 'outer,
                Msg::Goodbye { user } => {
                    // Recycle the departed connection's scheduler slot
                    // so a long-lived daemon's per-user state is
                    // bounded by peak concurrency, not connections-ever.
                    if let Some(slot) = user_index.remove(&user) {
                        for req in core.retire_user(slot) {
                            if let Some(id) = req.resume {
                                snapshots.remove(&id); // orphaned checkpoint
                            }
                            if let Some(p) = pending.remove(&req.job) {
                                fail_job(&mut batches, p.batch, "client disconnected".into());
                            }
                        }
                        free_slots.insert(slot);
                    }
                }
                Msg::Resume { reply } => {
                    paused = false;
                    round_due = core.has_pending();
                    let _ = reply.send(ok(vec![]));
                }
                Msg::SetPolicy { user, name, reply } => {
                    let slot = user_slot(&mut user_index, &mut free_slots, &mut next_fresh, user);
                    let r = if core.set_user_policy(slot, &name) {
                        round_due = core.has_pending();
                        ok(vec![("policy", s(name))])
                    } else {
                        err_val(&format!("unknown policy {name:?}"))
                    };
                    let _ = reply.send(r);
                }
                Msg::Submit { user, jobs, reply } => {
                    let slot = user_slot(&mut user_index, &mut free_slots, &mut next_fresh, user);
                    let mut batch = Batch {
                        reply,
                        remaining: jobs.len(),
                        latencies_us: Vec::new(),
                        modelled_us: Vec::new(),
                        error: None,
                    };
                    for job in jobs {
                        let token = next_token;
                        next_token += 1;
                        // Unknown accelerators fail fast at admission.
                        match core.submit(slot, token, &job.accname, job.tiles, None) {
                            Ok(()) => {
                                pending.insert(token, PendingJob::new(job, next_batch));
                                round_due = true;
                            }
                            Err(e) => {
                                batch.error = Some(e);
                                batch.remaining -= 1;
                            }
                        }
                    }
                    if batch.remaining == 0 {
                        finish(batch); // empty or fully rejected
                    } else {
                        batches.insert(next_batch, batch);
                        next_batch += 1;
                    }
                }
                _ => unreachable!("handle_cheap services every other message"),
            }
            continue; // drain every queued message before dispatching
        }
        if paused {
            continue;
        }

        if !round_due {
            // Advance the virtual clock to the next completion(s); the
            // freed modules stay resident for reuse, and the newly
            // idle capacity warrants a fresh round.  Execution happens
            // HERE (deferred from dispatch): entries missing from
            // `inflight` were preempted mid-span (or are ticks) and
            // only advance the clock — the simulator's exact rule.
            if let Some(&Reverse((t, _, _))) = completions.peek() {
                vnow = t;
                while let Some(&Reverse((t2, _, _))) = completions.peek() {
                    if t2 != t {
                        break;
                    }
                    let Reverse((_, sq, anchor)) = completions.pop().unwrap();
                    if let Some(inf) = inflight.remove(&sq) {
                        if running_seq.get(&anchor) == Some(&sq) {
                            running_seq.remove(&anchor);
                        }
                        core.complete(anchor);
                        finish_inflight(&mut cynq, &mut snapshots, &mut batches, inf);
                    }
                }
                round_due = core.has_pending();
            }
            continue;
        }
        round_due = false;

        // One scheduling round at the current virtual time: place as
        // many requests as the policy allows.  Reconfigurations are
        // mirrored onto the hardware immediately; compute is deferred
        // to the decision's virtual completion (or preemption point).
        core.begin_round_at(vnow);
        let mut placed = false;
        let mut stopping = false;
        loop {
            let t_sched = Instant::now();
            let Some(d) = core.next_decision() else { break };
            // Only committed decisions count toward the Table-4 mean —
            // the terminal empty scan would skew it.
            stats
                .sched_ns
                .fetch_add(t_sched.elapsed().as_nanos() as u64, Ordering::Relaxed);
            stats.sched_decisions.fetch_add(1, Ordering::Relaxed);
            // Publish the core's counters before any client can observe
            // this decision's batch reply — readers must never see
            // pre-decision totals.
            mirror_counters(&stats, core.counters());
            placed = true;

            if d.kind == DecisionKind::Preempt {
                // Cancel the victim's virtual completion, run the slice
                // the virtual clock says finished, checkpoint the
                // accelerator, and re-link the proto job so the later
                // Resume decision finds its context again.
                if let Some(vseq) = running_seq.remove(&d.anchor) {
                    if let Some(inf) = inflight.remove(&vseq) {
                        let done = inf.d.tiles - d.tiles;
                        let mut carry_us = inf.carry_us;
                        let mut failed = inf.err;
                        // A preempted Resume never reaches finish_inflight,
                        // so its own pending snapshot is consumed (and
                        // applied) here — same shared helper, so the two
                        // paths cannot drift.
                        let restored = take_and_restore_snapshot(&mut cynq, &mut snapshots, &inf);
                        if failed.is_none() {
                            let h = inf.handle.expect("loaded dispatch without handle");
                            let t0 = Instant::now();
                            let r = restored
                                .and_then(|()| run_tiles(&mut cynq, h, &inf.job, done))
                                .and_then(|()| {
                                    let snap = cynq
                                        .checkpoint_accelerator(h)
                                        .map_err(|e| e.to_string())?;
                                    snapshots
                                        .insert(d.ckpt.expect("preempt without ckpt id"), snap);
                                    Ok(())
                                });
                            if let Err(e) = r {
                                failed = Some(e);
                            }
                            carry_us += t0.elapsed().as_secs_f64() * 1e6;
                        }
                        let carry_modelled_us = inf.carry_modelled_us
                            + vnow.saturating_sub(inf.start_ns) as f64 / 1e3;
                        pending.insert(
                            d.job,
                            PendingJob {
                                job: inf.job,
                                batch: inf.batch,
                                carry_us,
                                carry_modelled_us,
                                failed,
                            },
                        );
                    }
                }
                continue;
            }

            // Virtual service latency from the shared cost model —
            // identical to the simulator's for the same decision.
            let busy_others = core.busy_anchors().saturating_sub(1);
            let lat = core.service_ns(&d, busy_others);
            core.mark_running(&d, vnow, vnow + lat);

            let p = pending.remove(&d.job).expect("decision for unknown job token");
            let mut handle = None;
            let mut err = p.failed;
            // Mirror the configuration effect even when an earlier slice
            // already failed (err pre-set): the core's region map has
            // recorded this placement either way, and skipping the load
            // would leave the hardware's residency permanently diverged
            // at this anchor.  Only compute is gated on `err`.
            match ensure_module(&mut cynq, &mut resident, &d) {
                Ok(h) => handle = Some(h),
                Err(fail) => {
                    if fail.module_missing {
                        // The (re)load itself failed: forget the
                        // core's residency bookkeeping so the next
                        // decision reconfigures instead of reusing
                        // a phantom instance forever.
                        core.evict(d.anchor);
                    }
                    if err.is_none() {
                        err = Some(fail.msg);
                    }
                }
            }
            if d.kind == DecisionKind::Run {
                stats.jobs.fetch_add(1, Ordering::Relaxed);
            }
            if d.replicated {
                stats.replicated_jobs.fetch_add(1, Ordering::Relaxed);
            }
            completions.push(Reverse((vnow + lat, seq, d.anchor)));
            running_seq.insert(d.anchor, seq);
            inflight.insert(
                seq,
                Inflight {
                    job: p.job,
                    batch: p.batch,
                    handle,
                    err,
                    start_ns: vnow,
                    lat_ns: lat,
                    carry_us: p.carry_us,
                    carry_modelled_us: p.carry_modelled_us,
                    d,
                },
            );
            seq += 1;

            // Keep cheap RPCs (connects, mem ops, stats) responsive
            // between decisions. State-changing messages are deferred
            // to the inbox so arrivals keep the simulator's
            // between-rounds cadence (decision-sequence parity).
            while let Ok(m) = rx.try_recv() {
                match handle_cheap(
                    m,
                    &mut cynq,
                    &core,
                    &mut paused,
                    &mut user_index,
                    &mut free_slots,
                    &mut next_fresh,
                ) {
                    None => {}
                    Some(Msg::Stop) => {
                        stopping = true;
                        break;
                    }
                    Some(other) => inbox.push_back(other),
                }
            }
            if stopping || paused {
                break; // hold the rest of the round
            }
        }
        // Mirror the core's counters once more: the terminal
        // next_decision() scan may have deferred users (skips).
        mirror_counters(&stats, core.counters());

        // Requests the core rejected instead of dispatching (unknown
        // accelerator past admission, or a policy naming an unknown
        // variant): surface the reason to the waiting client — the
        // dispatcher itself stays alive.
        for (req, reason) in core.take_rejected() {
            if let Some(id) = req.resume {
                snapshots.remove(&id);
            }
            if let Some(p) = pending.remove(&req.job) {
                fail_job(&mut batches, p.batch, reason);
            }
        }

        if stopping {
            break 'outer;
        }

        // Preemption-check cadence — the core-owned rule the simulator
        // uses verbatim, so the two paths cannot drift apart on when a
        // re-check round happens (that would break decision parity).
        if let Some(t) = core.preempt_tick_due(&mut next_tick, vnow) {
            completions.push(Reverse((t, seq, TICK_ANCHOR)));
            seq += 1;
        }

        if !placed && !paused && inflight.is_empty() && core.has_pending() {
            // Stall guard: nothing running, nothing placeable, so no
            // future completion can unblock these requests — fail them
            // instead of hanging their clients.
            for req in core.drain_pending() {
                let policy_name = core.policy_name_of(req.user);
                if let Some(id) = req.resume {
                    snapshots.remove(&id);
                }
                if let Some(p) = pending.remove(&req.job) {
                    fail_job(
                        &mut batches,
                        p.batch,
                        format!(
                            "request for {:?} is unplaceable under policy {policy_name:?}",
                            req.accel
                        ),
                    );
                }
            }
        }
    }
}

/// Consume a Resume dispatch's pending register-file snapshot and,
/// when its module is live, restore it.  Shared by normal completion
/// ([`finish_inflight`]) and preempt-of-a-Resume so the two paths
/// cannot drift; consuming unconditionally keeps the snapshot map
/// leak-free even when the dispatch already failed (the snapshot is
/// then just discarded).  `Ok` for non-Resume dispatches.  A failed
/// restore rolls back to an error — the module itself is untouched and
/// stays reusable.
fn take_and_restore_snapshot(
    cynq: &mut Cynq,
    snapshots: &mut HashMap<u64, AccelSnapshot>,
    inf: &Inflight,
) -> Result<(), String> {
    if inf.d.kind != DecisionKind::Resume {
        return Ok(());
    }
    let id = inf.d.ckpt.expect("resume without checkpoint id");
    let snap = snapshots
        .remove(&id)
        .ok_or_else(|| format!("internal: checkpoint {id} has no snapshot"))?;
    match inf.handle {
        Some(h) => cynq.restore_accelerator(h, &snap).map_err(|e| e.to_string()),
        // The (re)load already failed (error recorded at dispatch);
        // the snapshot is discarded with it.
        None => Ok(()),
    }
}

/// Execute a dispatch at its virtual completion: restore the checkpoint
/// for resumes, program the operand registers, run every tile, and
/// settle the batch reply.  Errors recorded at dispatch (failed loads)
/// surface here too.
fn finish_inflight(
    cynq: &mut Cynq,
    snapshots: &mut HashMap<u64, AccelSnapshot>,
    batches: &mut HashMap<usize, Batch>,
    inf: Inflight,
) {
    let mut err = inf.err;
    let t0 = Instant::now();
    // A Resume consumes its snapshot however it ends — a checkpoint
    // whose resume errored must not sit in the map forever.
    let restored = take_and_restore_snapshot(cynq, snapshots, &inf);
    if err.is_none() {
        let h = inf.handle.expect("loaded dispatch without handle");
        if let Err(e) = restored.and_then(|()| run_tiles(cynq, h, &inf.job, inf.d.tiles)) {
            err = Some(e);
        }
    }
    let b = batches.get_mut(&inf.batch).expect("decision for unknown batch");
    match err {
        None => {
            b.latencies_us.push(inf.carry_us + t0.elapsed().as_secs_f64() * 1e6);
            b.modelled_us.push(inf.carry_modelled_us + inf.lat_ns as f64 / 1e3);
        }
        Some(e) => b.error = Some(e),
    }
    b.remaining -= 1;
    if b.remaining == 0 {
        let b = batches.remove(&inf.batch).unwrap();
        finish(b);
    }
}

/// Publish the core's [`SchedCounters`] into the daemon's atomics —
/// the single scheduling-counter source both paths report from.
fn mirror_counters(stats: &DaemonStats, c: &SchedCounters) {
    stats.reconfig_loads.store(c.reconfigs, Ordering::Relaxed);
    stats.reuse_hits.store(c.reuses, Ordering::Relaxed);
    stats.skips.store(c.skips, Ordering::Relaxed);
    stats.replications.store(c.replications, Ordering::Relaxed);
    stats.preemptions.store(c.preemptions, Ordering::Relaxed);
    stats.resumes.store(c.resumes, Ordering::Relaxed);
}

/// Answer a message that needs no scheduling-state change (mem ops,
/// connection Hello, stats/log queries, pause) — callable both from
/// the top-level drain and mid-round, so long rounds don't head-of-line
/// block cheap RPCs. Returns the message back when it *does* change
/// scheduling state (Submit, SetPolicy, Resume, Goodbye, Stop) for the
/// caller to process at round boundaries.
fn handle_cheap(
    msg: Msg,
    cynq: &mut Cynq,
    core: &SchedCore,
    paused: &mut bool,
    user_index: &mut HashMap<u64, usize>,
    free_slots: &mut std::collections::BTreeSet<usize>,
    next_fresh: &mut usize,
) -> Option<Msg> {
    match msg {
        Msg::Mem { op, reply } => {
            let _ = reply.send(mem_op(cynq, op));
        }
        Msg::Hello { user, reply } => {
            let slot = user_slot(user_index, free_slots, next_fresh, user);
            let _ = reply.send(ok(vec![("user", i(user as i64)), ("slot", i(slot as i64))]));
        }
        Msg::Query { reply } => {
            let _ = reply.send(stats_value(core, *paused));
        }
        Msg::QueryLog { limit, reply } => {
            let skip = limit.map_or(0, |n| core.decision_log().count().saturating_sub(n));
            let _ = reply.send(core.decision_log().skip(skip).cloned().collect());
        }
        Msg::Pause { reply } => {
            *paused = true;
            let _ = reply.send(ok(vec![]));
        }
        other => return Some(other),
    }
    None
}

/// The `stats` RPC reply: queue depth + the core's shared counters.
fn stats_value(core: &SchedCore, paused: bool) -> Value {
    let c = core.counters();
    ok(vec![
        ("queued", i(core.pending() as i64)),
        ("reconfigs", i(c.reconfigs as i64)),
        ("reuses", i(c.reuses as i64)),
        ("skips", i(c.skips as i64)),
        ("replications", i(c.replications as i64)),
        ("preemptions", i(c.preemptions as i64)),
        ("resumes", i(c.resumes as i64)),
        ("paused", i(paused as i64)),
    ])
}

/// Scheduler slot for a daemon connection id: the existing binding, a
/// recycled slot (lowest first, keeping round-robin order stable), or
/// a fresh one.
fn user_slot(
    map: &mut HashMap<u64, usize>,
    free: &mut std::collections::BTreeSet<usize>,
    next_fresh: &mut usize,
    user: u64,
) -> usize {
    *map.entry(user).or_insert_with(|| {
        if let Some(&slot) = free.iter().next() {
            free.remove(&slot);
            slot
        } else {
            let slot = *next_fresh;
            *next_fresh += 1;
            slot
        }
    })
}

/// How a decision's hardware mirror failed. `module_missing` tells the
/// dispatcher whether the core's residency bookkeeping must be rolled
/// back (load never happened) or the module is resident and reusable
/// (compute-only failure).
struct ExecFailure {
    msg: String,
    module_missing: bool,
}

/// Mirror a decision's *configuration* effect onto the hardware at
/// schedule time: evict overlapped modules and (re)load the chosen
/// variant at its anchor, or look up the reused resident instance.
/// Compute is deferred (see [`finish_inflight`] / the preempt branch).
fn ensure_module(
    cynq: &mut Cynq,
    resident: &mut HashMap<usize, (LoadedAccel, usize)>,
    d: &Decision,
) -> Result<LoadedAccel, ExecFailure> {
    let missing = |msg: String| ExecFailure { msg, module_missing: true };
    if d.reconfigure {
        // The core already replaced these modules in its bookkeeping;
        // evict every resident module overlapping the new span.
        let stale: Vec<usize> = resident
            .iter()
            .filter(|&(&a, &(_, span))| a < d.anchor + d.span && a + span > d.anchor)
            .map(|(&a, _)| a)
            .collect();
        for a in stale {
            if let Some((h, _)) = resident.remove(&a) {
                cynq.unload(h).map_err(|e| missing(e.to_string()))?;
            }
        }
        let (h, _reconfig_latency) = cynq
            .load_accelerator_at(&d.accel, &d.variant, d.anchor)
            .map_err(|e| missing(e.to_string()))?;
        resident.insert(d.anchor, (h, d.span));
        Ok(h)
    } else {
        match resident.get(&d.anchor) {
            Some(&(h, _)) => Ok(h),
            None => Err(missing(format!(
                "internal: reuse at unresident anchor {}",
                d.anchor
            ))),
        }
    }
}

/// Program the job's operand registers and run `tiles` work items.
/// Failures keep the module resident — it stays reusable.
fn run_tiles(cynq: &mut Cynq, h: LoadedAccel, job: &Job, tiles: usize) -> Result<(), String> {
    for (reg, val) in &job.params {
        cynq.write_reg(h, reg, PhysAddr(*val)).map_err(|e| e.to_string())?;
    }
    for _ in 0..tiles {
        cynq.run(h).map_err(|e| e.to_string())?;
    }
    Ok(())
}

fn mem_op(cynq: &mut Cynq, op: MemOp) -> Value {
    match op {
        MemOp::Alloc { bytes } => match cynq.alloc(bytes) {
            Ok(a) => ok(vec![("addr", i(a.0 as i64))]),
            Err(e) => err_val(&e.to_string()),
        },
        MemOp::Free { addr } => match cynq.mem.free(PhysAddr(addr)) {
            Ok(()) => ok(vec![]),
            Err(e) => err_val(&e.to_string()),
        },
        MemOp::Write { addr, data } => match cynq.write_f32(PhysAddr(addr), &data) {
            Ok(()) => ok(vec![]),
            Err(e) => err_val(&e.to_string()),
        },
        MemOp::Read { addr, count } => match cynq.read_f32(PhysAddr(addr), count) {
            Ok(data) => ok(vec![("b64", s(proto::f32s_to_b64(&data)))]),
            Err(e) => err_val(&e.to_string()),
        },
        MemOp::Import { shm, offset, count, addr } => {
            match SharedMem::open(&shm)
                .map_err(|e| e.to_string())
                .and_then(|m| m.read_f32(offset, count).map_err(|e| e.to_string()))
                .and_then(|data| {
                    cynq.write_f32(PhysAddr(addr), &data).map_err(|e| e.to_string())
                }) {
                Ok(()) => ok(vec![]),
                Err(e) => err_val(&e),
            }
        }
        MemOp::Export { addr, count, shm, offset } => {
            match cynq
                .read_f32(PhysAddr(addr), count)
                .map_err(|e| e.to_string())
                .and_then(|data| {
                    SharedMem::open(&shm)
                        .map_err(|e| e.to_string())
                        .and_then(|mut m| m.write_f32(offset, &data).map_err(|e| e.to_string()))
                }) {
                Ok(()) => ok(vec![]),
                Err(e) => err_val(&e),
            }
        }
    }
}

fn ok(mut fields: Vec<(&str, Value)>) -> Value {
    fields.insert(0, ("status", s("ok")));
    obj(fields)
}

fn err_val(e: &str) -> Value {
    obj(vec![("status", s("err")), ("error", s(e))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::daemon::FpgaRpc;
    use std::sync::Mutex;

    static LOCK: Mutex<()> = Mutex::new(());

    fn sock(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("fos_daemon_{name}_{}.sock", std::process::id()))
    }

    fn start(name: &str) -> (Daemon, PathBuf) {
        let path = sock(name);
        let d = Daemon::start(&path, ShellBoard::Ultra96, Catalog::load_default().unwrap())
            .unwrap();
        (d, path)
    }

    #[test]
    fn single_client_vadd_end_to_end() {
        let _g = LOCK.lock().unwrap();
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let (_d, path) = start("vadd");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let a = rpc.alloc(4 * 4096).unwrap();
        let b = rpc.alloc(4 * 4096).unwrap();
        let c = rpc.alloc(4 * 4096).unwrap();
        let xs: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let ys: Vec<f32> = (0..4096).map(|i| (i * 2) as f32).collect();
        rpc.write_f32(a, &xs).unwrap();
        rpc.write_f32(b, &ys).unwrap();
        let job = Job::new(
            "vadd",
            vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
        );
        let report = rpc.run(&[job]).unwrap();
        assert_eq!(report.latencies_us.len(), 1);
        assert!(report.modelled_us[0] > 0.0);
        let out = rpc.read_f32(c, 4096).unwrap();
        for k in 0..4096 {
            assert_eq!(out[k], (k * 3) as f32);
        }
    }

    #[test]
    fn two_tenants_interleave_and_share() {
        let _g = LOCK.lock().unwrap();
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let (d, path) = start("multi");
        let mk = |rpc: &mut FpgaRpc, n: usize| -> (u64, u64, u64, Vec<Job>) {
            let a = rpc.alloc(4 * 4096).unwrap();
            let b = rpc.alloc(4 * 4096).unwrap();
            let c = rpc.alloc(4 * 4096).unwrap();
            rpc.write_f32(a, &vec![1.0; 4096]).unwrap();
            rpc.write_f32(b, &vec![2.0; 4096]).unwrap();
            let jobs = (0..n)
                .map(|_| {
                    Job::new(
                        "vadd",
                        vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
                    )
                })
                .collect();
            (a, b, c, jobs)
        };
        let path2 = path.clone();
        let t1 = std::thread::spawn(move || {
            let mut rpc = FpgaRpc::connect(&path2).unwrap();
            let (_, _, c, jobs) = mk(&mut rpc, 4);
            rpc.run(&jobs).unwrap();
            rpc.read_f32(c, 4096).unwrap()
        });
        let path3 = path.clone();
        let t2 = std::thread::spawn(move || {
            let mut rpc = FpgaRpc::connect(&path3).unwrap();
            let (_, _, c, jobs) = mk(&mut rpc, 4);
            rpc.run(&jobs).unwrap();
            rpc.read_f32(c, 4096).unwrap()
        });
        let o1 = t1.join().unwrap();
        let o2 = t2.join().unwrap();
        assert!(o1.iter().all(|&v| v == 3.0));
        assert!(o2.iter().all(|&v| v == 3.0));
        // Both users ran the same accelerator: reuse must have happened.
        assert!(d.stats().reuse_hits.load(Ordering::Relaxed) >= 6);
        assert_eq!(d.stats().jobs.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn single_tenant_backlog_replicates_on_live_path() {
        let _g = LOCK.lock().unwrap();
        let (d, path) = start("replicate");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let catalog = Catalog::load_default().unwrap();
        let params = crate::testutil::alloc_operand_params(&mut rpc, &catalog, "mandelbrot");
        // A backlog of long-running requests from ONE tenant: the
        // elastic core must fan them out over the free regions
        // (replication) instead of serialising on one module.
        let jobs: Vec<Job> = (0..4)
            .map(|_| Job::new("mandelbrot", params.clone()).with_tiles(4))
            .collect();
        // Scheduling decisions are made (and logged) even when the
        // compute backend is unavailable, so only gate on the reply.
        if let Ok(report) = rpc.run(&jobs) {
            assert_eq!(report.latencies_us.len(), 4);
        }
        assert!(
            d.stats().replications.load(Ordering::Relaxed) >= 1,
            "expected replication: {:?}",
            d.decision_log()
        );
        assert!(d.stats().replicated_jobs.load(Ordering::Relaxed) >= 1);
        let anchors: std::collections::HashSet<usize> =
            d.decision_log().iter().map(|x| x.anchor).collect();
        assert!(anchors.len() >= 2, "jobs stayed on {anchors:?}");
    }

    #[test]
    fn policy_knob_routes_tenant_to_fixed() {
        let _g = LOCK.lock().unwrap();
        let (d, path) = start("policy");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        rpc.set_policy(Policy::Fixed).unwrap();
        assert!(rpc.set_policy_name("themis").is_err());
        let catalog = Catalog::load_default().unwrap();
        let params = crate::testutil::alloc_operand_params(&mut rpc, &catalog, "mandelbrot");
        let jobs: Vec<Job> = (0..3)
            .map(|_| Job::new("mandelbrot", params.clone()).with_tiles(4))
            .collect();
        let _ = rpc.run(&jobs); // decisions land even if compute is stubbed
        // A fixed tenant keeps one region: no replication, one anchor.
        let anchors: std::collections::HashSet<usize> =
            d.decision_log().iter().map(|x| x.anchor).collect();
        assert_eq!(anchors.len(), 1, "fixed tenant moved: {anchors:?}");
        assert_eq!(d.stats().replications.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pause_resume_and_stats_roundtrip() {
        let _g = LOCK.lock().unwrap();
        let (_d, path) = start("pause");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        rpc.pause().unwrap();
        let s0 = rpc.sched_stats().unwrap();
        assert!(s0.paused);
        assert_eq!(s0.queued, 0);
        rpc.resume().unwrap();
        let s1 = rpc.sched_stats().unwrap();
        assert!(!s1.paused);
        // Connection still healthy.
        assert!(rpc.ping().is_ok());
    }

    #[test]
    fn shm_zero_copy_path() {
        let _g = LOCK.lock().unwrap();
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let (_d, path) = start("shm");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let shm_path = std::env::temp_dir().join(format!("fos_shm_{}.bin", std::process::id()));
        let mut shm = SharedMem::create(&shm_path, 4 * 4096 * 2).unwrap();
        let xs: Vec<f32> = (0..4096).map(|i| (i % 97) as f32).collect();
        shm.write_f32(0, &xs).unwrap();
        let a = rpc.alloc(4 * 4096).unwrap();
        let o = rpc.alloc(4 * 4096).unwrap();
        rpc.import_shm(&shm.path, 0, 4096, a).unwrap();
        let job = Job::new("aes", vec![("in_data".into(), a), ("out_data".into(), o)]);
        rpc.run(&[job]).unwrap();
        rpc.export_shm(o, 4096, &shm.path, 4 * 4096).unwrap();
        let out = shm.read_f32(4 * 4096, 4096).unwrap();
        // ARX cipher is a bijection: output differs from input everywhere
        // except possibly a few fixed points; check it's not identity.
        let same = out.iter().zip(&xs).filter(|(a, b)| a == b).count();
        assert!(same < 100, "{same} unchanged values");
    }

    #[test]
    fn unknown_accelerator_reports_error() {
        let _g = LOCK.lock().unwrap();
        let (_d, path) = start("err");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let job = Job::new("flux_capacitor", vec![]);
        assert!(matches!(rpc.run(&[job]), Err(proto::ProtoError::Remote(_))));
        // Connection still usable after an error.
        assert!(rpc.ping().is_ok());
    }
}
