//! Session layer: everything between a framed request and the
//! scheduler — request decoding, connection→scheduler-slot binding,
//! tenant identity with QoS refcounting, async submission tickets and
//! batch settlement.
//!
//! The [`Msg`] enum is the daemon's internal RPC vocabulary (one
//! variant per wire method, documented in
//! `rust/src/daemon/PROTOCOL.md`); [`decode_request`] translates a
//! parsed wire frame into it, preserving the original blocking
//! server's error contract exactly: schema errors on most fields
//! answer a structured `err` reply on the live connection, while a
//! missing `jobs` array (a protocol-level schema violation) tears the
//! connection down, just as the old `serve()` loop's `?` did.
//!
//! Tenant identity is reference-counted per connection: named tenants
//! (the `session` RPC) share an id across connections; anonymous
//! connections get a private one.  [`release_tenant`] drops one
//! connection's claim and retires the admission-pipeline state at
//! zero, shared by the Goodbye and rebind paths so the semantics
//! cannot drift between them.  Tickets ([`Ticket`], [`BatchSink`])
//! carry async `submit` results until `wait`/`poll`/`completions`
//! claims them, capped per connection by [`MAX_OPEN_TICKETS`].

use super::proto::{self, BufferHandle, Job, PROTO_MAX, PROTO_MIN};
use super::transport::ReplySink;
use crate::json::{arr, f, i, obj, s, Value};
use crate::sched::{AdmissionPipeline, Decision};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;

/// Open (pending + settled-but-unclaimed) async tickets one connection
/// may hold.  A fire-and-forget client that submits without ever
/// draining `wait`/`poll`/`completions` hits a structured busy reject
/// here instead of growing the dispatcher's ticket store forever.
pub const MAX_OPEN_TICKETS: usize = 1024;

pub(crate) enum Msg {
    /// A connection opened (sent by its first `ping` or `hello`): bind
    /// the daemon user id to a recycled scheduler slot.  `proto` is
    /// the version negotiated by a v2 `hello` (echoed in the reply);
    /// `None` for the legacy `ping` handshake.
    Hello {
        user: u64,
        proto: Option<u32>,
        reply: ReplySink,
    },
    /// A connection closed: retire its scheduler slot for reuse.
    Goodbye {
        user: u64,
    },
    /// Bind the connection to a named tenant + QoS class (weight and
    /// in-flight quota); several connections may share one tenant.
    /// When the daemon runs with authentication, `token` must match
    /// the tenant's minted token or the bind is denied.
    Session {
        user: u64,
        tenant: String,
        token: Option<String>,
        weight: u32,
        max_inflight: usize,
        reply: ReplySink,
    },
    /// Mint (or re-mint) a tenant token — the control-plane
    /// registration RPC, itself gated by the daemon's admin token.
    RegisterTenant {
        user: u64,
        admin_token: String,
        name: String,
        reply: ReplySink,
    },
    /// Per-tenant filtered view of the decision log: only entries
    /// belonging to the calling connection's tenant are returned.
    Audit {
        user: u64,
        limit: Option<usize>,
        reply: ReplySink,
    },
    /// Job batch. `wait: true` is the blocking `run` RPC (reply
    /// deferred to the batch's completion); `wait: false` is the
    /// non-blocking `submit` RPC (reply is an immediate ticket).
    Submit {
        user: u64,
        jobs: Vec<Job>,
        wait: bool,
        reply: ReplySink,
    },
    /// Block until the ticket settles (consumes it).
    Wait {
        user: u64,
        ticket: u64,
        reply: ReplySink,
    },
    /// Non-blocking ticket status (does not consume).
    Poll {
        user: u64,
        ticket: u64,
        reply: ReplySink,
    },
    /// Drain every settled ticket of this connection.
    Completions {
        user: u64,
        reply: ReplySink,
    },
    /// Tenant-scoped memory plane: `user` resolves to the issuing
    /// connection's tenant arena; `op` names buffers by opaque
    /// generational [`BufferHandle`], never by physical address.
    Mem {
        user: u64,
        op: MemOp,
        reply: ReplySink,
    },
    SetPolicy {
        user: u64,
        name: String,
        reply: ReplySink,
    },
    Pause {
        reply: ReplySink,
    },
    Resume {
        reply: ReplySink,
    },
    Query {
        reply: ReplySink,
    },
    /// Cluster-wide stats: totals, routing/steal counters and one
    /// object per board.
    QueryCluster {
        reply: ReplySink,
    },
    /// One board's scheduler counters and queue depth.
    QueryBoard {
        board: usize,
        reply: ReplySink,
    },
    /// Operator drain: board leaves the routable set, running work
    /// finishes in place ([`crate::sched::BoardHealth::Draining`]).
    DrainBoard {
        board: usize,
        reply: ReplySink,
    },
    /// Bring a drained (or failed) board back into rotation.
    ReviveBoard {
        board: usize,
        reply: ReplySink,
    },
    /// Tail of a decision log: one board's (`board: Some`) or the
    /// merged cluster log (`None`).  `limit: None` means "all retained
    /// entries" — still bounded by the core's ring cap; the reply
    /// clones only the tail, never scans the whole ring.
    QueryLog {
        board: Option<usize>,
        limit: Option<usize>,
        reply: mpsc::Sender<Vec<Decision>>,
    },
    /// The merged cluster log with its board tags — what the cluster
    /// fault-parity test compares against the simulator's
    /// `(board, decision)` sequence.
    QueryMergedTagged {
        reply: mpsc::Sender<Vec<(usize, Decision)>>,
    },
    Stop,
}

pub(crate) enum MemOp {
    Alloc { bytes: usize },
    Free { handle: BufferHandle },
    Write { handle: BufferHandle, data: Vec<f32> },
    Read { handle: BufferHandle, count: usize },
    Import { shm: PathBuf, offset: usize, count: usize, handle: BufferHandle },
    Export { handle: BufferHandle, count: usize, shm: PathBuf, offset: usize },
}

/// What one decoded wire frame means for the connection that sent it.
pub(crate) enum Decoded {
    /// Forward to the dispatcher; the reply arrives via the
    /// [`ReplySink`] embedded in the message.
    Dispatch(Msg),
    /// Answer right away without involving the dispatcher (schema
    /// errors, unknown methods).
    Immediate(Value),
    /// Protocol violation: tear the connection down, exactly as the
    /// blocking server's schema `?` did.
    Close,
}

/// Decode one parsed wire frame into the dispatcher vocabulary — the
/// reactor-side twin of the old blocking `serve()` match, preserving
/// its reply-vs-close error contract byte for byte.
pub(crate) fn decode_request(user: u64, msg: &Value, reply: ReplySink) -> Decoded {
    let method = msg.get("method").as_str().unwrap_or("");
    let m = match method {
        "ping" => Msg::Hello { user, proto: None, reply },
        // v2 handshake: the client offers a [min, max] version range;
        // the daemon picks the highest version both sides speak, or
        // answers a structured err naming its own range (never a
        // silent close — an old client gets a reply it can surface).
        "hello" => {
            let cmin = msg.get("min").as_u64().unwrap_or(1) as u32;
            let cmax = msg.get("max").as_u64().unwrap_or(u64::from(cmin)) as u32;
            if cmax < PROTO_MIN || cmin > PROTO_MAX {
                return Decoded::Immediate(obj(vec![
                    (
                        "status",
                        s("err"),
                    ),
                    (
                        "error",
                        s(format!(
                            "protocol version unsupported: client speaks {cmin}..{cmax}, daemon speaks {PROTO_MIN}..{PROTO_MAX}"
                        )),
                    ),
                    ("min_supported", i(i64::from(PROTO_MIN))),
                    ("max_supported", i(i64::from(PROTO_MAX))),
                ]));
            }
            Msg::Hello { user, proto: Some(cmax.min(PROTO_MAX)), reply }
        }
        // `run` blocks until the batch completes; `submit` returns
        // a ticket immediately (drain via wait/poll/completions).
        "run" | "submit" => {
            let wait = method == "run";
            let Ok(items) = msg.req_array("jobs") else {
                return Decoded::Close;
            };
            let jobs: Result<Vec<Job>, _> = items.iter().map(Job::from_value).collect();
            match jobs {
                Err(e) => return Decoded::Immediate(err_val(&e.to_string())),
                Ok(jobs) => Msg::Submit { user, jobs, wait, reply },
            }
        }
        "session" => match msg.req_str("tenant") {
            Err(e) => return Decoded::Immediate(err_val(&e)),
            Ok(tenant) => {
                let tenant = tenant.to_string();
                let token = msg.get("token").as_str().map(str::to_string);
                let weight = msg.get("weight").as_u64().unwrap_or(1).max(1) as u32;
                // 0 (or absent) = unbounded in-flight quota.
                let max_inflight = match msg.get("max_inflight").as_u64() {
                    Some(0) | None => usize::MAX,
                    Some(n) => n as usize,
                };
                Msg::Session { user, tenant, token, weight, max_inflight, reply }
            }
        },
        "register-tenant" => {
            let name = match msg.req_str("name") {
                Err(e) => return Decoded::Immediate(err_val(&e)),
                Ok(n) => n.to_string(),
            };
            let admin_token = match msg.req_str("admin_token") {
                Err(e) => return Decoded::Immediate(err_val(&e)),
                Ok(t) => t.to_string(),
            };
            Msg::RegisterTenant { user, admin_token, name, reply }
        }
        "audit" => {
            let limit = msg.get("limit").as_u64().map(|n| n as usize);
            Msg::Audit { user, limit, reply }
        }
        "wait" => match msg.req_u64("ticket") {
            Err(e) => return Decoded::Immediate(err_val(&e)),
            Ok(ticket) => Msg::Wait { user, ticket, reply },
        },
        "poll" => match msg.req_u64("ticket") {
            Err(e) => return Decoded::Immediate(err_val(&e)),
            Ok(ticket) => Msg::Poll { user, ticket, reply },
        },
        "completions" => Msg::Completions { user, reply },
        "policy" => match msg.req_str("policy") {
            Err(e) => return Decoded::Immediate(err_val(&e)),
            Ok(name) => {
                let name = name.to_string();
                Msg::SetPolicy { user, name, reply }
            }
        },
        "pause" => Msg::Pause { reply },
        "resume" => Msg::Resume { reply },
        "stats" => Msg::Query { reply },
        "cluster-stats" => Msg::QueryCluster { reply },
        "board-stats" => match msg.req_u64("board") {
            Err(e) => return Decoded::Immediate(err_val(&e)),
            Ok(board) => Msg::QueryBoard { board: board as usize, reply },
        },
        "drain-board" => match msg.req_u64("board") {
            Err(e) => return Decoded::Immediate(err_val(&e)),
            Ok(board) => Msg::DrainBoard { board: board as usize, reply },
        },
        "revive-board" => match msg.req_u64("board") {
            Err(e) => return Decoded::Immediate(err_val(&e)),
            Ok(board) => Msg::ReviveBoard { board: board as usize, reply },
        },
        "alloc" | "free" | "write" | "read" | "import" | "export" => {
            match parse_mem_op(method, msg) {
                Err(e) => return Decoded::Immediate(err_val(&e)),
                Ok(op) => Msg::Mem { user, op, reply },
            }
        }
        other => return Decoded::Immediate(err_val(&format!("unknown method {other:?}"))),
    };
    Decoded::Dispatch(m)
}

fn parse_mem_op(method: &str, msg: &Value) -> Result<MemOp, String> {
    // v2: buffers are named by opaque generational handles; the wire
    // field is `handle` and raw addresses are gone from the protocol.
    let handle = || msg.req_u64("handle").map(BufferHandle::from_raw);
    Ok(match method {
        "alloc" => MemOp::Alloc { bytes: msg.req_u64("bytes")? as usize },
        "free" => MemOp::Free { handle: handle()? },
        "write" => MemOp::Write {
            handle: handle()?,
            data: proto::b64_to_f32s(msg.req_str("b64")?).map_err(|e| e.to_string())?,
        },
        "read" => MemOp::Read {
            handle: handle()?,
            count: msg.req_u64("count")? as usize,
        },
        "import" => MemOp::Import {
            shm: msg.req_str("shm")?.into(),
            offset: msg.req_u64("offset")? as usize,
            count: msg.req_u64("count")? as usize,
            handle: handle()?,
        },
        "export" => MemOp::Export {
            handle: handle()?,
            count: msg.req_u64("count")? as usize,
            shm: msg.req_str("shm")?.into(),
            offset: msg.req_u64("offset")? as usize,
        },
        _ => unreachable!(),
    })
}

/// Where a finished batch's reply goes: straight back to a blocking
/// `run` caller, into the ticket store for the async
/// `wait`/`poll`/`completions` RPCs to claim, or nowhere — scenario
/// replay injects jobs with no client connection behind them.
pub(crate) enum BatchSink {
    Reply(ReplySink),
    Ticket(u64),
    Discard,
}

pub(crate) struct Batch {
    pub(crate) sink: BatchSink,
    pub(crate) remaining: usize,
    pub(crate) latencies_us: Vec<f64>,
    pub(crate) modelled_us: Vec<f64>,
    pub(crate) error: Option<String>,
}

/// One async submission's completion slot.  `done` holds the settled
/// reply until a `wait`/`completions` consumes it; `waiters` are
/// blocked `wait` callers to answer at settlement.
pub(crate) struct Ticket {
    pub(crate) user: u64,
    pub(crate) done: Option<Value>,
    pub(crate) waiters: Vec<ReplySink>,
}

/// Decrement a connection's open-ticket count (entry dropped at zero).
pub(crate) fn close_ticket(open: &mut HashMap<u64, usize>, user: u64) {
    if let Some(c) = open.get_mut(&user) {
        *c = c.saturating_sub(1);
        if *c == 0 {
            open.remove(&user);
        }
    }
}

/// Drop one connection's claim on tenant `id`: decrement the refcount
/// and, at zero, evict the name mapping and retire the pipeline state
/// (removed once drained) — shared by the Goodbye and Session-rebind
/// paths so retirement semantics cannot drift between them.  Returns
/// `true` when this was the last claim and the tenant is now retired —
/// the dispatcher's cue to tear down its memory arena and buffer
/// handles.
pub(crate) fn release_tenant(
    tenant_ids: &mut HashMap<String, usize>,
    tenant_refs: &mut HashMap<usize, usize>,
    admit: &mut AdmissionPipeline,
    id: usize,
) -> bool {
    let refs = tenant_refs.entry(id).or_insert(1);
    *refs = refs.saturating_sub(1);
    if *refs == 0 {
        tenant_refs.remove(&id);
        tenant_ids.retain(|_, &mut t| t != id);
        admit.retire(id);
        true
    } else {
        false
    }
}

/// Settle a finished batch: build the reply (error or latency arrays)
/// and deliver it to its sink — directly for a blocking `run`, or into
/// the ticket store (answering any blocked `wait` callers) for async
/// submissions.
pub(crate) fn finish(
    b: Batch,
    tickets: &mut HashMap<u64, Ticket>,
    open: &mut HashMap<u64, usize>,
) {
    let resp = match &b.error {
        Some(e) => err_val(e),
        None => ok(vec![
            (
                "latencies_us",
                arr(b.latencies_us.iter().map(|&x| f(x)).collect()),
            ),
            (
                "modelled_us",
                arr(b.modelled_us.iter().map(|&x| f(x)).collect()),
            ),
        ]),
    };
    match b.sink {
        BatchSink::Reply(tx) => {
            tx.send(resp);
        }
        // Scenario-replay batches have no claimant by construction.
        BatchSink::Discard => {}
        // A missing ticket means its connection departed: the reply
        // has no claimant and is dropped.
        BatchSink::Ticket(id) => match tickets.remove(&id) {
            None => {}
            Some(mut t) if t.waiters.is_empty() => {
                // Claimed later (wait/poll/completions).
                t.done = Some(resp);
                tickets.insert(id, t);
            }
            Some(t) => {
                for w in t.waiters {
                    w.send(resp.clone());
                }
                close_ticket(open, t.user); // consumed by the waiter(s)
            }
        },
    }
}

/// Fail one admitted-but-unfinished job of a batch, sending the batch
/// reply when it was the last outstanding unit — the single bookkeeping
/// path shared by client disconnects and the stall guard.
pub(crate) fn fail_job(
    batches: &mut HashMap<usize, Batch>,
    tickets: &mut HashMap<u64, Ticket>,
    open_tickets: &mut HashMap<u64, usize>,
    batch_id: usize,
    err: String,
) {
    if let Some(b) = batches.get_mut(&batch_id) {
        b.error = Some(err);
        b.remaining -= 1;
        if b.remaining == 0 {
            let b = batches.remove(&batch_id).unwrap();
            finish(b, tickets, open_tickets);
        }
    }
}

/// Scheduler slot for a daemon connection id: the existing binding, a
/// recycled slot (lowest first, keeping round-robin order stable), or
/// a fresh one.
pub(crate) fn user_slot(
    map: &mut HashMap<u64, usize>,
    free: &mut std::collections::BTreeSet<usize>,
    next_fresh: &mut usize,
    user: u64,
) -> usize {
    *map.entry(user).or_insert_with(|| {
        if let Some(&slot) = free.iter().next() {
            free.remove(&slot);
            slot
        } else {
            let slot = *next_fresh;
            *next_fresh += 1;
            slot
        }
    })
}

pub(crate) fn ok(mut fields: Vec<(&str, Value)>) -> Value {
    fields.insert(0, ("status", s("ok")));
    obj(fields)
}

pub(crate) fn err_val(e: &str) -> Value {
    obj(vec![("status", s("err")), ("error", s(e))])
}

/// Structured denied reply: `denied: 1` marks an isolation-domain
/// refusal (foreign buffer, bad or missing token, admin-gated RPC) —
/// distinct from schema errors so clients and tests can tell "you may
/// not" from "you asked wrong".  See the error taxonomy in
/// `rust/src/daemon/PROTOCOL.md`.
pub(crate) fn denied_val(e: &str) -> Value {
    obj(vec![("status", s("err")), ("error", s(e)), ("denied", i(1))])
}

/// Structured busy reply: `busy: 1` plus a deterministic retry hint —
/// what `enqueue` overflow and the connection cap answer instead of
/// stalling or silently dropping.
pub(crate) fn busy_val(msg: &str, retry_after_ms: u64) -> Value {
    obj(vec![
        ("status", s("err")),
        ("error", s(msg)),
        ("busy", i(1)),
        ("retry_after_ms", i(retry_after_ms.max(1) as i64)),
    ])
}
