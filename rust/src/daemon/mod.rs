//! The FOS multi-tenant daemon (§4.4, mode 3) and its client library.
//!
//! Architecture mirrors the paper's: applications talk to a daemon
//! process over an RPC channel (gRPC in the paper; a length-prefixed
//! JSON protocol over a Unix domain socket here — the offline vendor
//! set has no gRPC, and the IPC structure is identical), while bulk
//! data moves through shared memory so the socket never carries
//! payloads (the paper's zero-copy design). The daemon owns the FPGA:
//! a dispatcher thread drives the shared resource-elastic scheduler
//! core ([`crate::sched::SchedCore`]) — the same state machine the
//! offline simulator uses — so the live path performs variant
//! selection, multi-region spans, replication across free regions and
//! backlog-amortised reconfiguration avoidance (§4.4.3), executing
//! every decision through real PJRT compute in the Cynq stack.
//!
//! Tenants pick their scheduling policy over the wire
//! ([`FpgaRpc::set_policy`]): [`crate::sched::Policy::Elastic`] is the
//! default, [`crate::sched::Policy::Fixed`] reproduces the paper's
//! static baseline, and custom [`crate::sched::SchedPolicy`]
//! registrations are addressable by name.
//!
//! [`Daemon::start_cluster`] scales the same daemon to N boards: one
//! `Cynq` stack and scheduler shard per board behind one dispatcher,
//! with a [`crate::sched::PlacementKind`] policy routing requests and
//! `cluster-stats`/`board-stats` RPCs ([`FpgaRpc::cluster_stats`],
//! [`FpgaRpc::board_stats`]) exposing the per-board counters.

mod proto;
mod server;
mod client;
mod shm;

pub use client::{BoardStatsReport, ClusterStatsReport, FpgaRpc, RunReport, SchedStatsReport};
pub use proto::{read_msg, write_msg, Job, ProtoError};
pub use server::{BoardStats, Daemon, DaemonStats};
pub use shm::SharedMem;
