//! The FOS multi-tenant daemon (§4.4, mode 3) and its client library.
//!
//! Architecture mirrors the paper's: applications talk to a daemon
//! process over an RPC channel (gRPC in the paper; a length-prefixed
//! JSON protocol over a Unix domain socket here — the offline vendor
//! set has no gRPC, and the IPC structure is identical), while bulk
//! data moves through shared memory so the socket never carries
//! payloads (the paper's zero-copy design). The daemon owns the FPGA:
//! a dispatcher thread drives the shared resource-elastic scheduler
//! core ([`crate::sched::SchedCore`]) — the same state machine the
//! offline simulator uses — so the live path performs variant
//! selection, multi-region spans, replication across free regions and
//! backlog-amortised reconfiguration avoidance (§4.4.3), executing
//! every decision through real PJRT compute in the Cynq stack.
//!
//! Tenants pick their scheduling policy over the wire
//! ([`FpgaRpc::set_policy`]): [`crate::sched::Policy::Elastic`] is the
//! default, [`crate::sched::Policy::Fixed`] reproduces the paper's
//! static baseline, and custom [`crate::sched::SchedPolicy`]
//! registrations are addressable by name.
//!
//! [`Daemon::start_cluster`] scales the same daemon to N boards: one
//! `Cynq` stack and scheduler shard per board behind one dispatcher,
//! with a [`crate::sched::PlacementKind`] policy routing requests and
//! `cluster-stats`/`board-stats` RPCs ([`FpgaRpc::cluster_stats`],
//! [`FpgaRpc::board_stats`]) exposing the per-board counters.
//!
//! ## The submit/wait protocol (tenant-aware admission)
//!
//! Submission is asynchronous at the wire level; the blocking call is
//! a convenience wrapper:
//!
//! - **`session`** ([`FpgaRpc::set_session`]) binds the connection to
//!   a named *tenant* with a QoS class — an admission DRR `weight` and
//!   a token-bucket `max_inflight` quota.  Connections sharing a
//!   tenant name share one admission identity; connections that never
//!   call it get a private tenant with the permissive default class.
//! - **`submit`** ([`FpgaRpc::submit`]) enqueues a job batch into the
//!   tenant's *bounded* admission queue and replies immediately with a
//!   **ticket**.  A full queue answers a structured
//!   `busy`/`retry_after_ms` reply ([`ProtoError::Busy`]) — batches
//!   are accepted or refused atomically, never silently dropped, and
//!   the connection thread never parks on the dispatcher.
//! - **`wait`** ([`FpgaRpc::wait`]) blocks until the ticket settles
//!   and consumes it; **`poll`** ([`FpgaRpc::poll`]) is its
//!   non-blocking, non-consuming twin; **`completions`**
//!   ([`FpgaRpc::completions`]) drains every settled ticket of the
//!   connection in one round trip.
//! - **`run`** ([`FpgaRpc::run`]) is kept for compatibility: one round
//!   trip the daemon serves as submit+wait over the same pipeline.
//!   Blocking batches are exempt from `Busy` backpressure — a
//!   connection holds at most one, so the connection cap already
//!   bounds that state and old callers keep the old contract.
//!
//! Between submission and scheduling sits the shared
//! [`crate::sched::AdmissionPipeline`]: one batched ingest round per
//! scheduling round admits all eligible queued work in weighted
//! deficit-round-robin order under the per-tenant in-flight quotas —
//! the same state machine the simulator drives, which is what keeps
//! sim/daemon decision parity with QoS enabled (see
//! `sched/ARCHITECTURE.md`, *Admission & QoS*).
//!
//! ## Failure domain (board health + failover RPCs)
//!
//! The cluster dispatcher recovers from substrate faults — failed
//! partial reconfigurations (real `CynqError`s from
//! `load_accelerator_at`, or injected via
//! [`Daemon::start_cluster_with_faults`] / `fos daemon --fault-plan`),
//! transient run errors, and whole-board outages — by retrying with
//! exponential backoff and by checkpoint-migrating work off failed
//! boards (see `sched/ARCHITECTURE.md`, *Failure domain & recovery*).
//! The RPC surface:
//!
//! - **`drain-board`** ([`FpgaRpc::drain_board`]) takes a board out of
//!   the routable set (health `draining`): running and queued work
//!   finishes in place, new requests route around it.
//!   **`revive-board`** ([`FpgaRpc::revive_board`]) returns a drained
//!   or failed board to rotation (a failed board comes back blank).
//! - **`cluster-stats`** gained the failure-domain counters:
//!   `healthy` (routable boards), `failovers`, `migrations` (requests
//!   moved off failed boards), `lost_ns` (virtual execution destroyed
//!   by faults), `reconfig_failures` / `reconfig_retries` /
//!   `reconfig_rejections` (the backoff-retry pipeline), `run_faults`
//!   (transient errors re-queued) and `parked_retries` — parsed into
//!   [`ClusterStatsReport`].
//! - **`board-stats`** (and each board object of `cluster-stats`)
//!   gained `health`: `"healthy"`, `"draining"` or `"down"` —
//!   [`BoardStatsReport::health`].
//!
//! A request whose reconfiguration keeps failing past the per-accel
//! cap is answered with a structured error (the same reply path as
//! scheduler rejections), never silently dropped: batches still settle
//! and conservation holds under any fault plan (`tests/chaos.rs`).

mod proto;
mod server;
mod client;
mod shm;

pub use client::{
    BoardStatsReport, ClusterStatsReport, FpgaRpc, RunReport, SchedStatsReport,
    TenantStatsReport,
};
pub use proto::{read_msg, write_msg, Job, ProtoError};
pub use server::{BoardStats, Daemon, DaemonStats, DEFAULT_MAX_CONNECTIONS, MAX_OPEN_TICKETS};
pub use shm::SharedMem;
