//! The FOS multi-tenant daemon (§4.4, mode 3) and its client library.
//!
//! Architecture mirrors the paper's: applications talk to a daemon
//! process over an RPC channel (gRPC in the paper; a length-prefixed
//! JSON protocol over a Unix domain socket here — the offline vendor
//! set has no gRPC, and the IPC structure is identical), while bulk
//! data moves through shared memory so the socket never carries
//! payloads (the paper's zero-copy design).
//!
//! **The wire protocol — frame layout, every RPC, the ticket
//! lifecycle, `Busy { retry_after_ms }` backpressure and
//! version/compat notes — is specified in
//! `rust/src/daemon/PROTOCOL.md`.** This rustdoc covers only how the
//! pieces fit.
//!
//! The daemon is three layers (see also `rust/src/sched/ARCHITECTURE.md`,
//! *Network plane*):
//!
//! - [`transport`] — the event-driven reactor network plane:
//!   non-blocking accept + readiness polling (epoll on Linux behind a
//!   portable poller), connection state in a generational slab instead
//!   of a thread each, zero-copy frame reassembly into reusable
//!   per-connection buffers, and backpressure-aware write flushing.
//!   Runs as one reactor thread by default, or sharded across N
//!   ([`DaemonConfig::reactor_shards`], `fos daemon --reactor-shards`):
//!   a dedicated acceptor deals connections round-robin to per-shard
//!   reactors whose slab keys carry the shard id, all feeding the one
//!   dispatcher through a bounded ingest queue.
//! - `session` — the per-connection RPC surface: request decoding,
//!   tenant binding with QoS refcounting, the async ticket store and
//!   the structured `ok`/`err`/`busy` reply vocabulary.
//! - `dispatch` — the [`Daemon`] lifecycle and the dispatcher thread
//!   that owns the FPGA (Cynq stack) per board, drives the shared
//!   resource-elastic scheduler core ([`crate::sched::SchedCore`] /
//!   [`crate::sched::ClusterCore`]) — the same state machine the
//!   offline simulator uses, so sim/daemon decision parity holds —
//!   and replays completions through one virtual-time heap.
//!
//! Tenants pick their scheduling policy over the wire
//! ([`FpgaRpc::set_policy`]): [`crate::sched::Policy::Elastic`] is the
//! default, [`crate::sched::Policy::Fixed`] reproduces the paper's
//! static baseline, and custom [`crate::sched::SchedPolicy`]
//! registrations are addressable by name.
//!
//! [`Daemon::start_cluster`] scales the same daemon to N boards: one
//! `Cynq` stack and scheduler shard per board behind one dispatcher,
//! with a [`crate::sched::PlacementKind`] policy routing requests and
//! `cluster-stats`/`board-stats` RPCs ([`FpgaRpc::cluster_stats`],
//! [`FpgaRpc::board_stats`]) exposing the per-board counters.
//! [`Daemon::start_cluster_with_faults`] injects a deterministic
//! [`crate::sched::FaultPlan`]; recovery (drain/failover, checkpoint
//! migration, reconfig retry with backoff) is documented in
//! `sched/ARCHITECTURE.md`, *Failure domain & recovery*, and the
//! corresponding RPCs (`drain-board`, `revive-board`, the
//! failure-domain counters of `cluster-stats`) in `PROTOCOL.md`.

mod client;
mod dispatch;
mod proto;
mod session;
mod shm;
pub mod transport;

pub use client::{
    AuditEntry, BoardStatsReport, ClusterStatsReport, FpgaRpc, RunReport, SchedStatsReport,
    TenantStatsReport,
};
pub use dispatch::{BoardStats, Daemon, DaemonConfig, DaemonStats};
pub use proto::{
    read_msg, write_msg, BufferHandle, Job, ProtoError, MAX_MSG, PROTO_MAX, PROTO_MIN,
};
pub use session::MAX_OPEN_TICKETS;
pub use shm::SharedMem;
pub use transport::DEFAULT_MAX_CONNECTIONS;
