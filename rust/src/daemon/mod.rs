//! The FOS multi-tenant daemon (§4.4, mode 3) and its client library.
//!
//! Architecture mirrors the paper's: applications talk to a daemon
//! process over an RPC channel (gRPC in the paper; a length-prefixed
//! JSON protocol over a Unix domain socket here — the offline vendor
//! set has no gRPC, and the IPC structure is identical), while bulk
//! data moves through shared memory so the socket never carries
//! payloads (the paper's zero-copy design). The daemon owns the FPGA:
//! a dispatcher thread round-robins acceleration requests across user
//! connections (cooperative, run-to-completion — §4.4.3), reusing
//! loaded accelerators when possible and reconfiguring otherwise, and
//! drives real PJRT compute through the same Cynq stack single-tenant
//! code uses.

mod proto;
mod server;
mod client;
mod shm;

pub use client::FpgaRpc;
pub use proto::{read_msg, write_msg, Job, ProtoError};
pub use server::{Daemon, DaemonStats};
pub use shm::SharedMem;
