//! FpgaRpc — the client side of the daemon API (Listings 4–5).
//!
//! Buffers are named by opaque generational [`BufferHandle`]s scoped
//! to the connection's tenant; physical addresses never cross the
//! wire.  The connection opens with the v2 `hello` handshake
//! (protocol version negotiation — see `rust/src/daemon/PROTOCOL.md`).
//!
//! ```no_run
//! use fos::daemon::{FpgaRpc, Job};
//! let mut rpc = FpgaRpc::connect("/tmp/fos.sock").unwrap();
//! let a = rpc.alloc(4 * 4096).unwrap();
//! let b = rpc.alloc(4 * 4096).unwrap();
//! let c = rpc.alloc(4 * 4096).unwrap();
//! rpc.write_f32(a, &vec![1.0; 4096]).unwrap();
//! rpc.write_f32(b, &vec![2.0; 4096]).unwrap();
//! let job = Job::new(
//!     "vadd",
//!     vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
//! );
//! rpc.run(&[job]).unwrap();
//! let sum = rpc.read_f32(c, 4096).unwrap();
//! ```

use super::proto::{self, read_msg, write_msg, BufferHandle, Job, ProtoError, PROTO_MAX, PROTO_MIN};
use crate::json::{arr, i, obj, s, Value};
use crate::sched::Policy;
use std::os::unix::net::UnixStream;
use std::path::Path;
use std::time::{Duration, Instant};

/// Scheduler-side counters as reported by the daemon's `stats` method
/// (mirrors the shared [`crate::sched::SchedCounters`]).
#[derive(Debug, Clone, Default)]
pub struct SchedStatsReport {
    /// Work the daemon is holding: queued for admission plus admitted
    /// but not yet scheduled.
    pub queued: u64,
    /// The admission-pipeline share of `queued` (not yet ingested).
    pub admit_queued: u64,
    pub reconfigs: u64,
    pub reuses: u64,
    pub skips: u64,
    pub replications: u64,
    /// Running requests checkpointed and requeued (time-domain
    /// preemption).
    pub preemptions: u64,
    /// Checkpointed remainders re-dispatched.
    pub resumes: u64,
    /// Dispatching is held (see [`FpgaRpc::pause`]).
    pub paused: bool,
    /// One entry per live tenant (admission + scheduling accounting).
    pub tenants: Vec<TenantStatsReport>,
}

/// One tenant's slice of the daemon's `stats` reply.
#[derive(Debug, Clone, Default)]
pub struct TenantStatsReport {
    pub tenant: u64,
    /// DRR weight of the tenant's QoS class.
    pub weight: u64,
    /// Requests waiting in the tenant's admission queue.
    pub queued: u64,
    /// Admitted-but-uncompleted requests (held in-flight tokens).
    pub inflight: u64,
    /// Requests accepted into the admission queue, ever.
    pub enqueued: u64,
    /// Requests handed to the scheduler by batched ingest.
    pub admitted: u64,
    /// Completed dispatches (scheduler accounting).
    pub completed: u64,
    /// Dispatches checkpointed by preemption.
    pub preempted: u64,
    /// Requests refused with `Busy` backpressure (per request, so a
    /// refused 10-job batch counts 10; the daemon-wide
    /// `DaemonStats::busy_rejections` counts refused *batches*).
    pub busy_rejected: u64,
    /// Requests rejected by the scheduler mid-flight.
    pub sched_rejected: u64,
}

/// One board's slice of the daemon's `cluster-stats`/`board-stats`
/// replies (mirrors one scheduler shard's counters).
#[derive(Debug, Clone, Default)]
pub struct BoardStatsReport {
    /// Board name (`Ultra96`, `ZCU102`, ...).
    pub board: String,
    /// Board index (the id `board_stats` is keyed by).
    pub index: u64,
    /// Failure-domain health: `"healthy"`, `"draining"` or `"down"`.
    pub health: String,
    pub queued: u64,
    pub running: u64,
    pub reconfigs: u64,
    pub reuses: u64,
    pub skips: u64,
    pub replications: u64,
    pub preemptions: u64,
    pub resumes: u64,
}

/// The daemon's `cluster-stats` reply: placement policy, routing and
/// work-stealing counters, cluster totals and one entry per board.
#[derive(Debug, Clone, Default)]
pub struct ClusterStatsReport {
    pub placement: String,
    pub boards: Vec<BoardStatsReport>,
    /// Requests routed to a board at admission.
    pub routed: u64,
    /// Requests moved between boards by work stealing.
    pub steals: u64,
    pub queued: u64,
    pub reconfigs: u64,
    pub reuses: u64,
    pub preemptions: u64,
    pub resumes: u64,
    /// Boards currently routable (health `healthy`).
    pub healthy: u64,
    /// Boards failed over (running + queued work migrated).
    pub failovers: u64,
    /// Requests migrated off failed boards.
    pub migrations: u64,
    /// Virtual ns of execution destroyed by faults.
    pub lost_ns: u64,
    /// Reconfiguration attempts that failed (injected or real).
    pub reconfig_failures: u64,
    /// Failed reconfigurations parked for a backoff retry.
    pub reconfig_retries: u64,
    /// Requests rejected at the reconfiguration retry cap.
    pub reconfig_rejections: u64,
    /// Dispatches re-queued after a transient run error.
    pub run_faults: u64,
    /// Requests currently parked (backoff retries + revival waits).
    pub parked_retries: u64,
    pub paused: bool,
}

/// Per-run latency report.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Daemon-side wallclock per request (µs).
    pub latencies_us: Vec<f64>,
    /// Modelled FPGA latency per request (µs).
    pub modelled_us: Vec<f64>,
    /// Client-observed round-trip for the whole call.
    pub round_trip: Duration,
}

/// One entry of the `audit` RPC reply: a scheduling decision of the
/// calling connection's tenant (and nothing of its neighbours').
#[derive(Debug, Clone, Default)]
pub struct AuditEntry {
    pub board: u64,
    pub tenant: u64,
    pub job: u64,
    pub accel: String,
    pub variant: String,
    pub anchor: u64,
    pub span: u64,
    pub tiles: u64,
    /// `Run`, `Resume` or `Preempt`.
    pub kind: String,
    pub reconfigure: bool,
    pub replicated: bool,
}

/// The client connection.
pub struct FpgaRpc {
    stream: UnixStream,
    /// User id the daemon assigned (from the handshake).
    pub user: Option<u64>,
    /// Protocol version negotiated by the `hello` handshake.
    pub proto_version: u32,
    /// Time spent establishing the connection (Table 4 "Initialize").
    pub connect_latency: Duration,
}

impl FpgaRpc {
    /// Connect and negotiate the protocol version: the client offers
    /// `[PROTO_MIN, PROTO_MAX]` and the daemon picks the highest
    /// version both sides speak — or answers a structured error
    /// naming its own range (surfaced as [`ProtoError::Remote`]).
    pub fn connect(path: impl AsRef<Path>) -> Result<FpgaRpc, ProtoError> {
        let t0 = Instant::now();
        let stream = UnixStream::connect(path.as_ref())?;
        let mut rpc = FpgaRpc {
            stream,
            user: None,
            proto_version: 0,
            connect_latency: Duration::ZERO,
        };
        let hello = rpc.call(obj(vec![
            ("method", s("hello")),
            ("min", i(i64::from(PROTO_MIN))),
            ("max", i(i64::from(PROTO_MAX))),
        ]))?;
        rpc.user = hello.get("user").as_u64();
        rpc.proto_version = hello.get("proto").as_u64().unwrap_or(0) as u32;
        rpc.connect_latency = t0.elapsed();
        Ok(rpc)
    }

    fn call(&mut self, msg: Value) -> Result<Value, ProtoError> {
        write_msg(&mut self.stream, &msg)?;
        let resp = read_msg(&mut self.stream)?;
        if resp.get("status").as_str() == Some("ok") {
            Ok(resp)
        } else if resp.get("busy").as_u64() == Some(1) {
            // Structured backpressure, not a failure: honour the hint
            // and retry.
            Err(ProtoError::Busy {
                message: resp.get("error").as_str().unwrap_or("busy").to_string(),
                retry_after_ms: resp.get("retry_after_ms").as_u64().unwrap_or(1),
            })
        } else {
            Err(ProtoError::Remote(
                resp.get("error").as_str().unwrap_or("unknown").to_string(),
            ))
        }
    }

    /// Round-trip latency probe (Table 4 "gRPC call to daemon").
    pub fn ping(&mut self) -> Result<Duration, ProtoError> {
        let t0 = Instant::now();
        self.call(obj(vec![("method", s("ping"))]))?;
        Ok(t0.elapsed())
    }

    /// Allocate contiguous device-visible memory in this connection's
    /// tenant arena; returns an opaque tenant-scoped [`BufferHandle`]
    /// to pass into [`Job`] params and the other memory RPCs.
    pub fn alloc(&mut self, bytes: usize) -> Result<BufferHandle, ProtoError> {
        let r = self.call(obj(vec![
            ("method", s("alloc")),
            ("bytes", i(bytes as i64)),
        ]))?;
        r.get("handle")
            .as_u64()
            .map(BufferHandle::from_raw)
            .ok_or_else(|| ProtoError::Schema("alloc reply missing handle".into()))
    }

    pub fn free(&mut self, handle: BufferHandle) -> Result<(), ProtoError> {
        self.call(obj(vec![
            ("method", s("free")),
            ("handle", i(handle.raw() as i64)),
        ]))?;
        Ok(())
    }

    pub fn write_f32(&mut self, handle: BufferHandle, data: &[f32]) -> Result<(), ProtoError> {
        self.call(obj(vec![
            ("method", s("write")),
            ("handle", i(handle.raw() as i64)),
            ("b64", s(proto::f32s_to_b64(data))),
        ]))?;
        Ok(())
    }

    pub fn read_f32(
        &mut self,
        handle: BufferHandle,
        count: usize,
    ) -> Result<Vec<f32>, ProtoError> {
        let r = self.call(obj(vec![
            ("method", s("read")),
            ("handle", i(handle.raw() as i64)),
            ("count", i(count as i64)),
        ]))?;
        proto::b64_to_f32s(
            r.get("b64")
                .as_str()
                .ok_or_else(|| ProtoError::Schema("read reply missing b64".into()))?,
        )
    }

    /// Zero-copy input: the daemon pulls `count` f32s from the shared-
    /// memory file at `shm_path` + `offset` into the buffer named by
    /// `handle`.
    pub fn import_shm(
        &mut self,
        shm_path: &Path,
        offset: usize,
        count: usize,
        handle: BufferHandle,
    ) -> Result<(), ProtoError> {
        self.call(obj(vec![
            ("method", s("import")),
            ("shm", s(shm_path.to_string_lossy())),
            ("offset", i(offset as i64)),
            ("count", i(count as i64)),
            ("handle", i(handle.raw() as i64)),
        ]))?;
        Ok(())
    }

    /// Zero-copy output: device buffer -> shared-memory file.
    pub fn export_shm(
        &mut self,
        handle: BufferHandle,
        count: usize,
        shm_path: &Path,
        offset: usize,
    ) -> Result<(), ProtoError> {
        self.call(obj(vec![
            ("method", s("export")),
            ("handle", i(handle.raw() as i64)),
            ("count", i(count as i64)),
            ("shm", s(shm_path.to_string_lossy())),
            ("offset", i(offset as i64)),
        ]))?;
        Ok(())
    }

    /// Route this tenant to a built-in scheduling policy (the daemon
    /// default is [`Policy::Elastic`]).
    pub fn set_policy(&mut self, policy: Policy) -> Result<(), ProtoError> {
        self.set_policy_name(policy.name())
    }

    /// Route this tenant to a policy by registered name — custom
    /// [`crate::sched::SchedPolicy`] implementations included.
    pub fn set_policy_name(&mut self, name: &str) -> Result<(), ProtoError> {
        self.call(obj(vec![("method", s("policy")), ("policy", s(name))]))?;
        Ok(())
    }

    /// Hold dispatching: submitted jobs queue but nothing is scheduled
    /// until [`FpgaRpc::resume`] — admission control for maintenance
    /// windows (and the deterministic-arrival hook the sim/daemon
    /// parity test uses).
    pub fn pause(&mut self) -> Result<(), ProtoError> {
        self.call(obj(vec![("method", s("pause"))]))?;
        Ok(())
    }

    pub fn resume(&mut self) -> Result<(), ProtoError> {
        self.call(obj(vec![("method", s("resume"))]))?;
        Ok(())
    }

    /// Bind this connection to a named tenant with a QoS class: `weight`
    /// is the admission DRR weight, `max_inflight` the token-bucket
    /// in-flight quota (`0` = unbounded).  Several connections naming
    /// the same tenant share one admission identity (queue, quota,
    /// weight) and one memory isolation domain.  On an authenticated
    /// daemon (`--tenants`), `token` must carry the tenant's bearer
    /// token or the bind is denied.  Returns the daemon's tenant id.
    pub fn set_session(
        &mut self,
        tenant: &str,
        token: Option<&str>,
        weight: u32,
        max_inflight: usize,
    ) -> Result<u64, ProtoError> {
        let mut fields = vec![
            ("method", s("session")),
            ("tenant", s(tenant)),
            ("weight", i(weight as i64)),
            ("max_inflight", i(max_inflight as i64)),
        ];
        if let Some(t) = token {
            fields.push(("token", s(t)));
        }
        let r = self.call(obj(fields))?;
        r.get("tenant")
            .as_u64()
            .ok_or_else(|| ProtoError::Schema("session reply missing tenant".into()))
    }

    /// Mint (or re-mint) a tenant's bearer token — the control-plane
    /// registration RPC, gated by the daemon's admin token.
    pub fn register_tenant(
        &mut self,
        admin_token: &str,
        name: &str,
    ) -> Result<String, ProtoError> {
        let r = self.call(obj(vec![
            ("method", s("register-tenant")),
            ("admin_token", s(admin_token)),
            ("name", s(name)),
        ]))?;
        r.get("token")
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| ProtoError::Schema("register-tenant reply missing token".into()))
    }

    /// This tenant's filtered view of the daemon's decision log (the
    /// `audit` RPC): at most `limit` most-recent entries, all of them
    /// belonging to the calling connection's tenant.
    pub fn audit(&mut self, limit: Option<usize>) -> Result<Vec<AuditEntry>, ProtoError> {
        let mut fields = vec![("method", s("audit"))];
        if let Some(n) = limit {
            fields.push(("limit", i(n as i64)));
        }
        let r = self.call(obj(fields))?;
        let items = r.get("decisions").as_array().cloned().unwrap_or_default();
        Ok(items
            .iter()
            .map(|v| {
                let num = |key: &str| v.get(key).as_u64().unwrap_or(0);
                let txt = |key: &str| v.get(key).as_str().unwrap_or("").to_string();
                AuditEntry {
                    board: num("board"),
                    tenant: num("tenant"),
                    job: num("job"),
                    accel: txt("accel"),
                    variant: txt("variant"),
                    anchor: num("anchor"),
                    span: num("span"),
                    tiles: num("tiles"),
                    kind: txt("kind"),
                    reconfigure: num("reconfigure") != 0,
                    replicated: num("replicated") != 0,
                }
            })
            .collect())
    }

    /// Non-blocking offload: enqueue the batch and return a ticket
    /// immediately (the connection thread never waits on scheduling).
    /// Claim the result with [`FpgaRpc::wait`], [`FpgaRpc::poll`] or
    /// [`FpgaRpc::completions`].  A full admission queue answers
    /// [`ProtoError::Busy`] with a retry hint instead of blocking.
    pub fn submit(&mut self, jobs: &[Job]) -> Result<u64, ProtoError> {
        let r = self.call(obj(vec![
            ("method", s("submit")),
            ("jobs", arr(jobs.iter().map(|j| j.to_value()).collect())),
        ]))?;
        r.get("ticket")
            .as_u64()
            .ok_or_else(|| ProtoError::Schema("submit reply missing ticket".into()))
    }

    /// Block until `ticket` settles; consumes the ticket.
    pub fn wait(&mut self, ticket: u64) -> Result<RunReport, ProtoError> {
        let t0 = Instant::now();
        let r = self.call(obj(vec![("method", s("wait")), ("ticket", i(ticket as i64))]))?;
        Ok(run_report(&r, t0.elapsed()))
    }

    /// Non-blocking ticket status: `None` while in flight,
    /// `Some(Ok(report))` / `Some(Err(_))` once settled.  Does not
    /// consume the ticket — `wait`/`completions` do.
    #[allow(clippy::type_complexity)]
    pub fn poll(
        &mut self,
        ticket: u64,
    ) -> Result<Option<Result<RunReport, ProtoError>>, ProtoError> {
        let t0 = Instant::now();
        let r = self.call(obj(vec![("method", s("poll")), ("ticket", i(ticket as i64))]))?;
        if r.get("done").as_u64() != Some(1) {
            return Ok(None);
        }
        Ok(Some(settle_result(r.get("result"), t0.elapsed())))
    }

    /// Drain every settled async ticket of this connection, in ticket
    /// order (the `completions` RPC).
    #[allow(clippy::type_complexity)]
    pub fn completions(
        &mut self,
    ) -> Result<Vec<(u64, Result<RunReport, ProtoError>)>, ProtoError> {
        let t0 = Instant::now();
        let r = self.call(obj(vec![("method", s("completions"))]))?;
        let mut out = Vec::new();
        if let Some(items) = r.get("completions").as_array() {
            for item in items {
                let ticket = item.get("ticket").as_u64().unwrap_or(0);
                out.push((ticket, settle_result(item.get("result"), t0.elapsed())));
            }
        }
        Ok(out)
    }

    /// Snapshot of the daemon's shared scheduler counters.
    pub fn sched_stats(&mut self) -> Result<SchedStatsReport, ProtoError> {
        let r = self.call(obj(vec![("method", s("stats"))]))?;
        let num = |key: &str| r.get(key).as_u64().unwrap_or(0);
        let tenants = r
            .get("tenants")
            .as_array()
            .map(|a| a.iter().map(tenant_report).collect())
            .unwrap_or_default();
        Ok(SchedStatsReport {
            queued: num("queued"),
            admit_queued: num("admit_queued"),
            reconfigs: num("reconfigs"),
            reuses: num("reuses"),
            skips: num("skips"),
            replications: num("replications"),
            preemptions: num("preemptions"),
            resumes: num("resumes"),
            paused: num("paused") != 0,
            tenants,
        })
    }

    /// Cluster-wide scheduling stats: placement policy, routing and
    /// work-stealing counters, and one [`BoardStatsReport`] per board.
    pub fn cluster_stats(&mut self) -> Result<ClusterStatsReport, ProtoError> {
        let r = self.call(obj(vec![("method", s("cluster-stats"))]))?;
        let num = |key: &str| r.get(key).as_u64().unwrap_or(0);
        let boards = r
            .get("boards")
            .as_array()
            .map(|a| a.iter().map(board_report).collect())
            .unwrap_or_default();
        Ok(ClusterStatsReport {
            placement: r.get("placement").as_str().unwrap_or("").to_string(),
            boards,
            routed: num("routed"),
            steals: num("steals"),
            queued: num("queued"),
            reconfigs: num("reconfigs"),
            reuses: num("reuses"),
            preemptions: num("preemptions"),
            resumes: num("resumes"),
            healthy: num("healthy"),
            failovers: num("failovers"),
            migrations: num("migrations"),
            lost_ns: num("lost_ns"),
            reconfig_failures: num("reconfig_failures"),
            reconfig_retries: num("reconfig_retries"),
            reconfig_rejections: num("reconfig_rejections"),
            run_faults: num("run_faults"),
            parked_retries: num("parked_retries"),
            paused: num("paused") != 0,
        })
    }

    /// Operator drain: board `board` leaves the routable set (health
    /// `draining`) — running and queued work finishes in place, new
    /// requests route around it.  Undo with [`FpgaRpc::revive_board`].
    pub fn drain_board(&mut self, board: usize) -> Result<String, ProtoError> {
        let r = self.call(obj(vec![
            ("method", s("drain-board")),
            ("board", i(board as i64)),
        ]))?;
        Ok(r.get("health").as_str().unwrap_or("").to_string())
    }

    /// Bring a drained (or failed) board back into rotation.
    pub fn revive_board(&mut self, board: usize) -> Result<String, ProtoError> {
        let r = self.call(obj(vec![
            ("method", s("revive-board")),
            ("board", i(board as i64)),
        ]))?;
        Ok(r.get("health").as_str().unwrap_or("").to_string())
    }

    /// One board's scheduling counters and queue depth.  Errors for an
    /// out-of-range board index.
    pub fn board_stats(&mut self, board: usize) -> Result<BoardStatsReport, ProtoError> {
        let r = self.call(obj(vec![
            ("method", s("board-stats")),
            ("board", i(board as i64)),
        ]))?;
        Ok(board_report(&r))
    }

    /// Offload data-parallel acceleration requests (Listing 4's
    /// `fpgaRpc.Run(job)`). Blocks until every request completed.
    /// One round trip: the daemon serves `run` as submit+wait over the
    /// same admission pipeline the async ticket RPCs use — blocking
    /// batches are exempt from `Busy` backpressure (a connection can
    /// only ever hold one), so old callers keep the old contract.
    pub fn run(&mut self, jobs: &[Job]) -> Result<RunReport, ProtoError> {
        let t0 = Instant::now();
        let r = self.call(obj(vec![
            ("method", s("run")),
            ("jobs", arr(jobs.iter().map(|j| j.to_value()).collect())),
        ]))?;
        Ok(run_report(&r, t0.elapsed()))
    }
}

/// Parse a settled batch reply into a [`RunReport`].
fn run_report(r: &Value, round_trip: Duration) -> RunReport {
    let nums = |key: &str| -> Vec<f64> {
        r.get(key)
            .as_array()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
            .unwrap_or_default()
    };
    RunReport {
        latencies_us: nums("latencies_us"),
        modelled_us: nums("modelled_us"),
        round_trip,
    }
}

/// Interpret an embedded ticket result (from `poll`/`completions`):
/// the stored reply keeps its own ok/err status.
fn settle_result(r: &Value, round_trip: Duration) -> Result<RunReport, ProtoError> {
    if r.get("status").as_str() == Some("ok") {
        Ok(run_report(r, round_trip))
    } else {
        Err(ProtoError::Remote(
            r.get("error").as_str().unwrap_or("unknown").to_string(),
        ))
    }
}

/// Parse one tenant object of a `stats` reply.
fn tenant_report(v: &Value) -> TenantStatsReport {
    let num = |key: &str| v.get(key).as_u64().unwrap_or(0);
    TenantStatsReport {
        tenant: num("tenant"),
        weight: num("weight"),
        queued: num("queued"),
        inflight: num("inflight"),
        enqueued: num("enqueued"),
        admitted: num("admitted"),
        completed: num("completed"),
        preempted: num("preempted"),
        busy_rejected: num("busy_rejected"),
        sched_rejected: num("sched_rejected"),
    }
}

/// Parse one board object of a `cluster-stats`/`board-stats` reply.
fn board_report(v: &Value) -> BoardStatsReport {
    let num = |key: &str| v.get(key).as_u64().unwrap_or(0);
    BoardStatsReport {
        board: v.get("board").as_str().unwrap_or("").to_string(),
        index: num("index"),
        health: v.get("health").as_str().unwrap_or("").to_string(),
        queued: num("queued"),
        running: num("running"),
        reconfigs: num("reconfigs"),
        reuses: num("reuses"),
        skips: num("skips"),
        replications: num("replications"),
        preemptions: num("preemptions"),
        resumes: num("resumes"),
    }
}
